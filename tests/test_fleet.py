"""Multi-process serving fleet: concurrent-writer journal safety, the
incremental JournalFollower, architecture-fingerprint artifact resolution,
file-based fleet membership, and the FleetService transport (round-trip
correctness, executor respawn, shared-journal decision coherence)."""

import json
import os
import subprocess
import sys
import time
from pathlib import Path

import numpy as np
import pytest

import repro
from repro.core import AdsalaRuntime, ModelRegistry
from repro.core.durable import (DurableStore, JournalFollower,
                                append_journal, encode_record, read_records)
from repro.core.knobs import Knob
from repro.core.registry import (fingerprint_distance, fingerprint_slug,
                                 host_fingerprint)
from repro.distributed.elastic import FleetMembership

SRC = str(Path(repro.__file__).resolve().parents[1])


# ---------------------------------------------------------------------------
# satellite: flock-guarded append_journal under 4 concurrent processes
# ---------------------------------------------------------------------------

_HAMMER = """
import sys
sys.path.insert(0, {src!r})
from repro.core.durable import append_journal
wid, n, path = int(sys.argv[1]), int(sys.argv[2]), sys.argv[3]
for i in range(n):
    append_journal(path, {{"writer": wid, "i": i}})
"""


def test_concurrent_append_journal_no_torn_or_dropped_records(tmp_path):
    """4 processes hammer one journal; read-back must see every record
    intact — zero drops, zero tears, no interleaving."""
    path = tmp_path / "state.json.journal"
    n_writers, n_each = 4, 200
    script = _HAMMER.format(src=SRC)
    procs = [subprocess.Popen([sys.executable, "-c", script,
                               str(w), str(n_each), str(path)])
             for w in range(n_writers)]
    for p in procs:
        assert p.wait(timeout=120) == 0
    records, dropped = read_records(path)
    assert dropped == 0
    assert len(records) == n_writers * n_each
    # every (writer, i) pair exactly once — an interleaved (torn) pair of
    # appends would corrupt both records, a lost wakeup would drop one
    seen = {(r["writer"], r["i"]) for r in records}
    assert len(seen) == n_writers * n_each
    # per-writer order is preserved (appends are atomic whole records)
    for w in range(n_writers):
        idxs = [r["i"] for r in records if r["writer"] == w]
        assert idxs == sorted(idxs)


# ---------------------------------------------------------------------------
# JournalFollower: incremental polls, torn tails, truncation
# ---------------------------------------------------------------------------

def test_follower_incremental_poll(tmp_path):
    path = tmp_path / "j.journal"
    f = JournalFollower(path)
    assert f.poll() == [] and not f.changed()     # missing file is empty
    append_journal(path, {"a": 1})
    assert f.changed()
    assert f.poll() == [{"a": 1}]
    assert not f.changed()
    assert f.poll() == []                          # nothing new
    append_journal(path, {"b": 2})
    append_journal(path, {"c": 3})
    assert [r for r in f.poll()] == [{"b": 2}, {"c": 3}]


def test_follower_carries_midappend_tail_then_completes(tmp_path):
    """A record observed mid-flush (no trailing newline, bad checksum so
    far) is carried, not dropped, and delivered once complete."""
    path = tmp_path / "j.journal"
    f = JournalFollower(path)
    full = "\n" + encode_record({"x": 42})
    with open(path, "ab") as fh:
        fh.write(full[:len(full) // 2].encode())
    assert f.poll() == []                          # partial: carried
    assert f.dropped == 0
    with open(path, "ab") as fh:
        fh.write(full[len(full) // 2:].encode())
    assert f.poll() == [{"x": 42}]
    assert f.dropped == 0


def test_follower_counts_terminated_torn_record(tmp_path):
    path = tmp_path / "j.journal"
    f = JournalFollower(path)
    with open(path, "ab") as fh:
        fh.write(b"\ndeadbeef {garbage")            # torn forever
    append_journal(path, {"ok": 1})                 # successor terminates it
    assert f.poll() == [{"ok": 1}]
    assert f.dropped == 1


def test_follower_resets_on_truncation(tmp_path):
    """snapshot() absorbs + deletes the journal; a follower that observes
    the shrink replays from offset 0 (idempotent downstream)."""
    store = DurableStore(tmp_path / "state.json")
    f = store.follower()
    store.append({"k": 1})
    assert f.poll() == [{"k": 1}]
    store.snapshot([{"k": 1}])                      # journal deleted
    store.append({"k": 2})                          # new journal, smaller
    assert f.poll() == [{"k": 2}]
    assert f.dropped == 0


# ---------------------------------------------------------------------------
# architecture fingerprints: slug/distance/resolution order
# ---------------------------------------------------------------------------

def test_host_fingerprint_shape_and_slug_determinism():
    fp = host_fingerprint()
    assert set(fp) == {"cpu_model", "machine", "cores", "cache_line"}
    assert fp["cores"] >= 1 and fp["cache_line"] > 0
    assert fingerprint_slug(fp) == fingerprint_slug(dict(fp))
    json.dumps(fp)                                  # JSON-safe


def test_fingerprint_distance_weighting():
    base = {"cpu_model": "EPYC 7B13", "machine": "x86_64",
            "cores": 16, "cache_line": 64}
    same = dict(base)
    other_model = dict(base, cpu_model="Xeon 8481C")
    other_isa = dict(base, machine="aarch64")
    wider = dict(base, cores=32)
    assert fingerprint_distance(base, same) == 0.0
    # model mismatch dominates ISA, which dominates core-count deltas
    assert fingerprint_distance(base, other_model) > \
        fingerprint_distance(base, other_isa) > \
        fingerprint_distance(base, wider) > 0.0
    # log2 core ratio: 16→32 as far as 32→64
    assert fingerprint_distance(base, wider) == pytest.approx(
        fingerprint_distance(wider, dict(base, cores=64)))


def test_resolve_fingerprint_exact_nearest_flat(tmp_path):
    reg = ModelRegistry(tmp_path)
    me = {"cpu_model": "EPYC 7B13", "machine": "x86_64",
          "cores": 16, "cache_line": 64}
    cousin = dict(me, cores=32)
    stranger = {"cpu_model": "Graviton3", "machine": "aarch64",
                "cores": 64, "cache_line": 64}
    # flat: no arch/ entries at all → the root itself
    assert reg.resolve_fingerprint(me).root == reg.root
    assert reg.last_fingerprint_resolution["mode"] == "flat"
    # exact: the calibrated slot for this fingerprint
    sub = reg.for_fingerprint(me, create=True)
    assert sub.root == reg.root / "arch" / fingerprint_slug(me)
    got = reg.resolve_fingerprint(me)
    assert got.root == sub.root
    assert reg.last_fingerprint_resolution["mode"] == "exact"
    # nearest: an uncalibrated host borrows the closest architecture
    reg.for_fingerprint(stranger, create=True)
    got = reg.resolve_fingerprint(cousin)
    assert got.root == sub.root                     # cousin ≫ stranger
    res = reg.last_fingerprint_resolution
    assert res["mode"] == "nearest" and res["slug"] == fingerprint_slug(me)
    assert res["distance"] == pytest.approx(1.0)    # |log2(16/32)|
    # roster lists both calibrated slots
    assert {s for s, _ in reg.fingerprints()} == \
        {fingerprint_slug(me), fingerprint_slug(stranger)}


# ---------------------------------------------------------------------------
# fleet membership (distributed/elastic.py seam)
# ---------------------------------------------------------------------------

def test_fleet_membership_register_heartbeat_stale(tmp_path):
    m = FleetMembership(tmp_path / "members", stale_s=0.3)
    m.register("exec-1", slug="x86")
    m.register("exec-2")
    names = {r["name"] for r in m.members()}
    assert names == {"exec-1", "exec-2"}
    assert all(r["pid"] == os.getpid() for r in m.members())
    time.sleep(0.35)
    m.heartbeat("exec-1")                           # keep one alive
    assert {r["name"] for r in m.members()} == {"exec-1"}
    assert {r["name"] for r in m.members(live_only=False)} == \
        {"exec-1", "exec-2"}
    m.deregister("exec-1")
    m.deregister("exec-1")                          # idempotent
    assert m.members() == []


def test_fleet_membership_skips_torn_records(tmp_path):
    root = tmp_path / "members"
    m = FleetMembership(root)
    m.register("good")
    (root / "torn.json").write_text('{"name": "to')
    assert [r["name"] for r in m.members()] == ["good"]


# ---------------------------------------------------------------------------
# cross-process decision coherence, single-process analogue: two live
# runtimes share one journal through followers
# ---------------------------------------------------------------------------

class StubSub:
    """Minimal TunedSubroutine stand-in: fixed-knob model with observable
    evaluation count (mirrors the stub in test_runtime_cache)."""

    def __init__(self, backend, op="gemm", dtype_bytes=4):
        self.backend, self.op, self.dtype_bytes = backend, op, dtype_bytes
        self.knob = Knob((("bm", 128), ("bn", 128)))
        self.evals = 0

    def select(self, dims):
        self.evals += 1
        return self.knob


def _register_stub(rt, backend="cpu_blocked", version=0):
    sub = StubSub(backend)
    sub.artifact_version = version
    rt.register(sub)
    return sub


def test_two_runtimes_share_decisions_via_journal(tmp_path):
    reg = ModelRegistry(tmp_path)
    rt_a = AdsalaRuntime(cache_size=32)
    rt_b = AdsalaRuntime(cache_size=32)
    _register_stub(rt_a)
    sub_b = _register_stub(rt_b)
    rt_a.decision_journal = reg.journal_decision
    follower = reg.journal_follower()
    # A decides two shapes (miss path → journal appends)
    rt_a.select("gemm", (64, 64, 64), 4, backend="cpu_blocked")
    rt_a.select("gemm", (128, 64, 64), 4, backend="cpu_blocked")
    # B absorbs them: zero model evals for the same shapes afterwards
    absorbed = rt_b.absorb_journal(follower.poll())
    assert absorbed == 2
    assert rt_b.stats.journal_absorbed == 2
    rt_b.select("gemm", (64, 64, 64), 4, backend="cpu_blocked")
    rt_b.select("gemm", (128, 64, 64), 4, backend="cpu_blocked")
    assert sub_b.evals == 0
    assert rt_b.stats.cache_hits == 2


def test_quarantine_is_benched_fleet_wide_via_journal(tmp_path):
    reg = ModelRegistry(tmp_path)
    rt_a = AdsalaRuntime()
    rt_b = AdsalaRuntime()
    rt_a.decision_journal = reg.journal_decision
    follower = reg.journal_follower()
    bad = Knob((("bm", 128), ("bn", 128)))
    fb = Knob((("bm", 64), ("bn", 64)))
    rt_a.quarantine_knob("gemm", 4, "cpu_blocked", bad, fallback=fb,
                         ttl_s=30.0)
    rt_b.absorb_journal(follower.poll())
    assert rt_b.is_quarantined("gemm", 4, "cpu_blocked", bad)


def test_absorb_journal_idempotent_own_records(tmp_path):
    """A member's own journaled decisions come back around the shared
    file; re-absorbing them must be a harmless overwrite."""
    reg = ModelRegistry(tmp_path)
    rt = AdsalaRuntime()
    sub = _register_stub(rt)
    rt.decision_journal = reg.journal_decision
    follower = reg.journal_follower()
    knob = rt.select("gemm", (64, 64, 64), 4, backend="cpu_blocked")
    assert rt.absorb_journal(follower.poll()) == 1
    assert rt.cache_len() == 1
    assert rt.select("gemm", (64, 64, 64), 4,
                     backend="cpu_blocked") == knob
    assert sub.evals == 1                           # never re-evaluated


# ---------------------------------------------------------------------------
# FleetService: transport round trip, respawn, warm join (spawned
# executor processes — each pays a jax import, so traffic is tiny)
# ---------------------------------------------------------------------------

pytestmark_slow = pytest.mark.skipif(
    os.environ.get("ADSALA_SKIP_FLEET") == "1",
    reason="fleet process tests disabled")


@pytest.fixture(scope="module")
def fleet_cls():
    from repro.serving import FleetConfig, FleetService
    return FleetService, FleetConfig


@pytestmark_slow
def test_fleet_round_trip_and_close(fleet_cls):
    FleetService, FleetConfig = fleet_cls
    from repro.serving import ServeConfig
    rng = np.random.default_rng(7)
    svc = FleetService(
        fleet=FleetConfig(processes=2, membership=False),
        config=ServeConfig(backend="cpu_blocked", max_batch=4,
                           linger_ms=1.0))
    try:
        futs = []
        for _ in range(12):
            a = rng.standard_normal((48, 32)).astype(np.float32)
            b = rng.standard_normal((32, 24)).astype(np.float32)
            futs.append((svc.submit("gemm", (a, b)), a @ b))
        for f, want in futs:
            np.testing.assert_allclose(f.result(timeout=120), want,
                                       rtol=2e-4, atol=2e-4)
        assert svc.stats.completed == 12 and svc.stats.failed == 0
        stats = svc.fleet_stats()
        assert len(stats) == 2 and all(d["alive"] for d in stats)
    finally:
        svc.close()
    # close is idempotent and a post-close submit is rejected
    svc.close()
    from repro.serving import ServiceClosedError
    with pytest.raises(ServiceClosedError):
        svc.submit("gemm", (np.eye(8, dtype=np.float32),) * 2)


@pytestmark_slow
def test_fleet_executor_death_respawns_and_requeues(fleet_cls):
    FleetService, FleetConfig = fleet_cls
    from repro.serving import ServeConfig
    svc = FleetService(
        fleet=FleetConfig(processes=1, membership=False,
                          request_timeout_s=60.0),
        config=ServeConfig(backend="cpu_blocked", max_batch=2,
                           linger_ms=1.0))
    try:
        a = np.eye(16, dtype=np.float32)
        # murder the executor, then submit: the dispatcher must observe
        # the death, respawn into the same slot, and requeue the bucket
        svc._executors[0].proc.kill()
        svc._executors[0].proc.join(timeout=10)
        fut = svc.submit("gemm", (a, a))
        np.testing.assert_allclose(fut.result(timeout=180), a, atol=1e-5)
        assert svc.stats.worker_respawns >= 1
        assert svc.stats.completed == 1 and svc.stats.failed == 0
    finally:
        svc.close()


@pytestmark_slow
def test_fleet_warm_member_joins_with_zero_evals(fleet_cls, tmp_path):
    """The tentpole coherence claim, end to end: member 1 decides shapes
    against a real installed model (journaling each miss); a member added
    afterwards hydrates from the shared journal and never evaluates."""
    FleetService, FleetConfig = fleet_cls
    from repro.backends import get_backend
    from repro.core import install_backend
    from repro.serving import ServeConfig
    reg = ModelRegistry(tmp_path)
    sub_reg = reg.for_fingerprint(create=True)
    install_backend(get_backend("cpu_blocked"), ops=("gemm",),
                    n_samples=12, dim_lo=32, dim_hi=96,
                    max_footprint_bytes=1_000_000, tune_trials=1,
                    candidates=("LinearRegression",), registry=sub_reg,
                    seed=11)
    rng = np.random.default_rng(3)
    shapes = [(32, 32, 32), (48, 32, 32), (64, 48, 32)]
    svc = FleetService(
        fleet=FleetConfig(processes=1, registry_root=str(tmp_path)),
        config=ServeConfig(backend="cpu_blocked", max_batch=4,
                           linger_ms=1.0))
    try:
        futs = []
        for m, n, k in shapes:
            a = rng.standard_normal((m, k)).astype(np.float32)
            b = rng.standard_normal((k, n)).astype(np.float32)
            futs.append(svc.submit("gemm", (a, b)))
        for f in futs:
            f.result(timeout=180)
        first = svc.fleet_stats()[0]
        assert first["loaded"] == 1
        assert first["model_evals"] >= 1          # it really decided
        assert first["resolution"]["mode"] == "exact"
        info = svc.add_member()                   # ← the warm join
        assert info["warm_started"] >= len(shapes)
        # same shapes again: whoever serves them, NO member evaluates
        futs = []
        for m, n, k in shapes * 4:
            a = rng.standard_normal((m, k)).astype(np.float32)
            b = rng.standard_normal((k, n)).astype(np.float32)
            futs.append(svc.submit("gemm", (a, b)))
        for f in futs:
            f.result(timeout=180)
        stats = svc.fleet_stats()
        assert len(stats) == 2
        newcomer = stats[1]
        assert newcomer["model_evals"] == 0       # zero-eval warm join
        assert stats[0]["model_evals"] == first["model_evals"]
        # membership roster shows both executors
        members = FleetMembership(tmp_path / "members").members(
            live_only=False)
        assert len(members) == 2
    finally:
        svc.close()
