"""End-to-end behaviour tests for the paper's system (ADSALA): the full
install → persist → runtime-dispatch → measured-speedup loop on this host's
black-box BLAS, plus the dry-run cell machinery at reduced scale."""

import json
import subprocess
import sys
import time
from pathlib import Path

import numpy as np
import pytest

from repro.core import AdsalaRuntime, ModelRegistry, install_subroutine
from repro.core.timing import time_callable
from repro.kernels.cpu_blocked import make_operands, run_blocked
from repro.kernels.ops import knob_space_for

_SRC = str(Path(__file__).resolve().parents[1] / "src")


@pytest.fixture(scope="module")
def tuned_gemm():
    """A real (wall-clock) ADSALA install on the numpy blocked GEMM —
    miniature version of the paper's installation phase."""
    space = knob_space_for("gemm", sizes=(32, 64, 128))
    cache = {}

    def timer(dims, knob):
        if cache.get("d") != dims:
            cache["d"] = dims
            cache["ops"] = make_operands("gemm", dims, np.float32,
                                         seed=hash(dims) % 999)
        return time_callable(lambda: run_blocked("gemm", cache["ops"], knob),
                             warmup=0, repeats=1)

    return install_subroutine(
        "gemm", space, timer, n_samples=25, dim_lo=32, dim_hi=256,
        max_footprint_bytes=2_000_000, dtype_bytes=4,
        candidates=("LinearRegression", "DecisionTree", "XGBoost"),
        tune_trials=2, seed=0)


def test_install_produces_valid_artifact(tuned_gemm):
    assert tuned_gemm.model_name in ("LinearRegression", "DecisionTree",
                                     "XGBoost")
    assert len(tuned_gemm.reports) == 3
    knob = tuned_gemm.select((128, 128, 128))
    assert {"bm", "bk", "bn", "variant"} <= set(knob.dict)


def test_measured_speedup_vs_default_on_holdout(tuned_gemm):
    """The paper's evaluation: speedup = t_default / (t_predicted + t_eval)
    on fresh Halton-sampled dims, with *measured* wall-clock.  We assert the
    tuned config is no slower than the default in aggregate (CPU timing
    noise makes per-point assertions flaky)."""
    from repro.core.halton import sample_dims
    default = tuned_gemm.dataset.knob_space.candidates[
        tuned_gemm.dataset.default_knob_index()]
    # dims ≥96 keep op time ≳10× the eval time — below that regime the
    # memo cache is the amortiser (see EXPERIMENTS.md Table VII note)
    dims_list = sample_dims(8, 3, lo=96, hi=256, seed=99)
    t_def = t_tuned = 0.0
    for drow in dims_list:
        dims = tuple(int(v) for v in drow)
        operands = make_operands("gemm", dims, np.float32, seed=1)
        t0 = time.perf_counter()
        knob = tuned_gemm.select(dims)
        t_eval = time.perf_counter() - t0
        t_def += time_callable(
            lambda: run_blocked("gemm", operands, default), warmup=1,
            repeats=2)
        t_tuned += time_callable(
            lambda: run_blocked("gemm", operands, knob), warmup=1,
            repeats=2) + t_eval
    agg = t_def / t_tuned
    # single-core CI timing is noisy; this guards against gross regressions
    assert agg > 0.7, f"aggregate speedup {agg:.2f} unexpectedly poor"


def test_registry_runtime_end_to_end(tuned_gemm, tmp_path):
    reg = ModelRegistry(tmp_path)
    reg.save(tuned_gemm)
    rt = AdsalaRuntime()
    assert reg.load_into(rt) == 1
    k = rt.select("gemm", (96, 96, 96), dtype_bytes=4)
    assert k == tuned_gemm.select((96, 96, 96))
    assert rt.stats.calls == 1


def test_calibration_artifacts_exist_and_load():
    """Whatever calibration store the repo carries (runs/adsala) is loadable
    and drives the runtime for every backend-tagged artifact in it."""
    root = Path(__file__).resolve().parents[1] / "runs" / "adsala" / "models"
    if not root.exists():
        pytest.skip("calibration artifacts not present")
    reg = ModelRegistry(root)
    subs = reg.load_all()
    assert subs, "store exists but holds no artifacts"
    rt = AdsalaRuntime()
    assert reg.load_into(rt) == len(subs)
    assert set(rt.backends()) == {s.backend for s in subs}
    for sub in subs:
        dims = (200, 150, 100) if sub.op == "gemm" else (200, 150)
        knob = rt.select(sub.op, dims, dtype_bytes=sub.dtype_bytes,
                         backend=sub.backend)
        assert "bm" in knob.dict


@pytest.mark.slow
def test_dryrun_cell_small_mesh():
    """run_cell end-to-end on a tiny mesh in a subprocess (8 devices)."""
    prog = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import dataclasses
from pathlib import Path
import jax
import repro.launch.dryrun as dr
import repro.launch.mesh as mesh_mod
mesh_mod.make_production_mesh = \\
    lambda *, multi_pod=False: jax.make_mesh((4, 2), ("data", "model"))
dr.make_production_mesh = mesh_mod.make_production_mesh
import repro.configs as C
small = C.get_smoke_config("llama3-8b")
dr.get_config = lambda name: small
import repro.configs.base as B
B.SHAPES["tiny_train"] = B.Shape("tiny_train", 128, 8, "train")
dr.SHAPES = B.SHAPES
rec = dr.run_cell("llama3-8b", "tiny_train", "single", Path("/tmp/drt"))
print(json.dumps({"status": rec["status"],
                  "bottleneck": rec["roofline"]["bottleneck"],
                  "flops": rec["roofline"]["hlo_flops"]}))
"""
    import os
    env = dict(os.environ)
    env["PYTHONPATH"] = _SRC
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, timeout=900, env=env)
    assert out.returncode == 0, out.stderr[-4000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["status"] == "ok" and res["flops"] > 0
