"""AdsalaRuntime accounting hardening: concurrency stress on the stats
counters (aggregate must equal the per-backend sums under contention), LRU
decision-cache eviction order, warm-start export/import, and
ModelRegistry-level legacy-v1 artifact handling."""

import json
import random
import threading

import pytest

from repro.core import AdsalaRuntime, ModelRegistry, install_subroutine
from repro.core.knobs import Knob
from repro.kernels import ops


class StubSub:
    """Minimal TunedSubroutine stand-in: a fixed-knob 'model' whose
    evaluations are observable (the runtime only needs op/dtype_bytes/
    backend/select)."""

    def __init__(self, backend: str, op: str = "gemm",
                 dtype_bytes: int = 4) -> None:
        self.backend = backend
        self.op = op
        self.dtype_bytes = dtype_bytes
        self.knob = Knob((("bm", 128), ("bn", 128)))
        self.evals = 0

    def select(self, dims):
        self.evals += 1
        return self.knob


# ---------------------------------------------------------------------------
# concurrency stress: aggregate counters == sum of per-backend counters
# ---------------------------------------------------------------------------

def test_stats_consistent_under_concurrent_mixed_backend_load():
    rt = AdsalaRuntime(cache_size=4)      # small: constant LRU churn
    backends = ("b0", "b1")
    for name in backends:
        rt.register(StubSub(name))
    default = Knob((("bm", 64), ("bn", 64)))
    dims_pool = [(32 * i, 32, 32) for i in range(1, 7)]
    n_threads, n_iters = 8, 300
    errors = []

    def worker(tid):
        rng = random.Random(tid)
        try:
            for _ in range(n_iters):
                dims = rng.choice(dims_pool)
                roll = rng.random()
                if roll < 0.4:
                    rt.select("gemm", dims, 4, backend=rng.choice(backends))
                elif roll < 0.8:
                    rt.select_or_default("gemm", dims, 4, default,
                                         backend=rng.choice(backends))
                else:   # untuned backend → default path
                    rt.select_or_default("gemm", dims, 4, default,
                                         backend="untuned")
        except Exception as e:   # noqa: BLE001
            errors.append(e)

    # a stats reader races the workers: model_evals and eval_seconds are
    # updated together under the shard lock, so a snapshot must never show
    # the count without the time (the torn-read signature of reading the
    # pair lock-free)
    stop_reading = threading.Event()

    def stats_reader():
        try:
            while not stop_reading.is_set():
                s = rt.stats
                for name, b in s.backends.items():
                    if b.model_evals > 0:
                        assert b.eval_seconds > 0.0, \
                            f"{name}: torn evals/seconds snapshot"
                if s.model_evals > 0:
                    assert s.eval_seconds > 0.0
        except Exception as e:   # noqa: BLE001
            errors.append(e)

    reader = threading.Thread(target=stats_reader)
    reader.start()
    threads = [threading.Thread(target=worker, args=(t,))
               for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    stop_reading.set()
    reader.join()
    assert not errors

    s = rt.stats
    assert s.calls == n_threads * n_iters
    per = list(s.backends.values())
    for counter in ("calls", "cache_hits", "default_calls", "model_evals"):
        agg = getattr(s, counter)
        total = sum(getattr(b, counter) for b in per)
        assert agg == total, f"{counter}: aggregate {agg} != sum {total}"
    assert s.eval_seconds == pytest.approx(
        sum(b.eval_seconds for b in per), abs=1e-6)
    # every select is exactly one of: hit, model eval, default
    assert s.calls == s.cache_hits + s.model_evals + s.default_calls
    assert set(s.backends) == {"b0", "b1", "untuned"}
    assert s.backends["untuned"].default_calls == \
        s.backends["untuned"].calls


# ---------------------------------------------------------------------------
# LRU decision cache: eviction order + warm-start round trip
# ---------------------------------------------------------------------------

def test_lru_eviction_order():
    # touch_sample=1: every hit logs a recency touch, so the relaxed-LRU
    # fold reproduces exact LRU ordering deterministically
    rt = AdsalaRuntime(cache_size=3, touch_sample=1)
    sub = StubSub("b0")
    rt.register(sub)

    def dims_in_cache():
        return [tuple(e["dims"]) for e in rt.export_cache()]

    A, B, C, D = (32, 32, 32), (64, 32, 32), (96, 32, 32), (128, 32, 32)
    for d in (A, B, C):
        rt.select("gemm", d, 4, backend="b0")
    assert dims_in_cache() == [A, B, C]          # insertion order, LRU first
    rt.select("gemm", A, 4, backend="b0")        # hit refreshes A
    assert dims_in_cache() == [B, C, A]
    assert sub.evals == 3
    rt.select("gemm", D, 4, backend="b0")        # evicts B (now oldest)
    assert dims_in_cache() == [C, A, D]
    assert rt.cache_len() == 3
    evals_before = sub.evals
    rt.select("gemm", B, 4, backend="b0")        # B was evicted → re-eval
    assert sub.evals == evals_before + 1
    assert dims_in_cache() == [A, D, B]


def test_cache_export_import_skips_model_evals():
    rt = AdsalaRuntime()
    sub = StubSub("b0")
    rt.register(sub)
    shapes = [(32 * i, 32, 32) for i in range(1, 5)]
    for d in shapes:
        rt.select("gemm", d, 4, backend="b0")
    exported = rt.export_cache()
    assert len(exported) == len(shapes)

    warm = AdsalaRuntime()
    warm_sub = StubSub("b0")
    warm.register(warm_sub)
    assert warm.import_cache(exported) == len(shapes)
    for d in shapes:
        assert warm.select("gemm", d, 4, backend="b0") == sub.knob
    assert warm_sub.evals == 0
    assert warm.stats.model_evals == 0
    assert warm.stats.cache_hits == len(shapes)


def test_import_cache_respects_capacity():
    rt = AdsalaRuntime()
    rt.register(StubSub("b0"))
    for i in range(1, 7):
        rt.select("gemm", (32 * i, 32, 32), 4, backend="b0")
    small = AdsalaRuntime(cache_size=3)
    small.import_cache(rt.export_cache())
    assert small.cache_len() == 3
    # the newest three entries survive, in order
    assert [tuple(e["dims"]) for e in small.export_cache()] == \
        [(128, 32, 32), (160, 32, 32), (192, 32, 32)]


def test_import_cache_drops_knobs_outside_registered_space():
    """A cache persisted before a recalibration may name knobs the new
    candidate space no longer contains — those entries must not warm-start."""
    rt = AdsalaRuntime()
    rt.register(StubSub("b0"))
    rt.select("gemm", (32, 32, 32), 4, backend="b0")
    entries = rt.export_cache()
    stale = dict(entries[0])
    stale["knob"] = {"bm": 7, "bn": 7}          # never a candidate
    stale["dims"] = [64, 64, 64]

    class SpacedSub(StubSub):
        def __init__(self):
            super().__init__("b0")
            self.knob_space = type("S", (), {
                "candidates": [self.knob]})()

    warm = AdsalaRuntime()
    warm.register(SpacedSub())
    assert warm.import_cache(entries + [stale]) == 1
    assert [tuple(e["dims"]) for e in warm.export_cache()] == [(32, 32, 32)]
    # unregistered subroutines can't validate → import as-is
    bare = AdsalaRuntime()
    assert bare.import_cache([stale]) == 1


def test_decision_cache_persists_via_registry(tmp_path):
    reg = ModelRegistry(tmp_path)
    rt = AdsalaRuntime()
    rt.register(StubSub("b0"))
    rt.select("gemm", (64, 64, 64), 4, backend="b0")
    path = reg.save_decision_cache(rt)
    assert path == tmp_path / ModelRegistry.DECISION_CACHE
    # durable checksummed snapshot: magic header, a version-3 header
    # record, one record per cache entry — every record self-verifies
    from repro.core.durable import MAGIC, read_records
    assert path.read_text().startswith(MAGIC)
    records, dropped = read_records(path)
    assert dropped == 0
    assert records[0] == {"header": 1,
                          "version": ModelRegistry.DECISION_CACHE_VERSION}
    assert len(records) == 2 and records[1]["op"] == "gemm"

    warm = AdsalaRuntime()
    warm.register(StubSub("b0"))
    assert reg.load_decision_cache(warm) == 1
    warm.select("gemm", (64, 64, 64), 4, backend="b0")
    assert warm.stats.model_evals == 0 and warm.stats.cache_hits == 1


def test_load_decision_cache_missing_file_is_noop(tmp_path):
    rt = AdsalaRuntime()
    assert ModelRegistry(tmp_path).load_decision_cache(rt) == 0
    assert rt.cache_len() == 0


def test_load_decision_cache_rejects_unknown_version(tmp_path):
    reg = ModelRegistry(tmp_path)
    reg.decision_cache_path.parent.mkdir(parents=True, exist_ok=True)
    reg.decision_cache_path.write_text(
        json.dumps({"version": 99, "entries": []}))
    with pytest.raises(ValueError, match="version"):
        reg.load_decision_cache(AdsalaRuntime())


# ---------------------------------------------------------------------------
# ModelRegistry: legacy v1 (untagged) artifacts
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def real_sub():
    """One real tuned artifact (flat-time timer keeps the install fast)."""
    space = ops.knob_space_for("gemm", sizes=(32, 64))
    return install_subroutine(
        "gemm", space, lambda dims, knob: 1e-3, n_samples=12,
        dim_lo=32, dim_hi=64, max_footprint_bytes=1_000_000,
        tune_trials=1, candidates=("LinearRegression",), use_lof=False,
        backend="cpu_blocked")


def test_registry_loads_legacy_v1_as_pallas(tmp_path, real_sub):
    from repro.core.registry import pack_state
    state = real_sub.get_state()
    del state["backend"], state["version"]      # what a v1 writer produced
    (tmp_path / "gemm_b4.adsala").write_bytes(pack_state(state))

    reg = ModelRegistry(tmp_path)
    assert reg.backends() == ("pallas",)
    subs = reg.load_all()
    assert len(subs) == 1 and subs[0].backend == "pallas"
    # the filename-level filter agrees with the content-level default
    assert len(reg.load_all(backend="pallas")) == 1
    assert reg.load_all(backend="cpu_blocked") == []

    rt = AdsalaRuntime()
    assert reg.load_into(rt) == 1
    assert rt.has("gemm", 4, backend="pallas")
    assert not rt.has("gemm", 4, backend="cpu_blocked")


def test_registry_mixed_legacy_and_tagged(tmp_path, real_sub):
    from repro.core.registry import pack_state
    reg = ModelRegistry(tmp_path)
    reg.save(real_sub)                          # cpu_blocked__gemm_b4.adsala
    state = real_sub.get_state()
    del state["backend"], state["version"]
    (tmp_path / "gemm_b4.adsala").write_bytes(pack_state(state))

    assert reg.backends() == ("cpu_blocked", "pallas")
    rt = AdsalaRuntime()
    assert reg.load_into(rt) == 2
    assert rt.backends() == ("cpu_blocked", "pallas")
    # per-backend filtering unpacks only the matching files
    assert [s.backend for s in reg.load_all(backend="cpu_blocked")] == \
        ["cpu_blocked"]
