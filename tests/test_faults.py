"""Tests for the deterministic fault-injection plan (repro.serving.faults):
spec validation, occurrence windows (after/times/p), matching, determinism
under a seed, latency-only specs, reset, and thread-safety of the counters."""

import threading
import time

import pytest

from repro.serving import FaultPlan, FaultSpec, InjectedFault


def test_spec_validation():
    with pytest.raises(ValueError):
        FaultSpec(site="s", times=-1)
    with pytest.raises(ValueError):
        FaultSpec(site="s", after=-1)
    with pytest.raises(ValueError):
        FaultSpec(site="s", p=1.5)
    with pytest.raises(ValueError):
        FaultSpec(site="s", p=-0.1)
    with pytest.raises(ValueError):
        # neither an exception nor latency: the spec would be a no-op
        FaultSpec(site="s", exc=None, latency_s=0.0)


def test_default_spec_fires_exactly_once():
    plan = FaultPlan([FaultSpec(site="s")])
    with pytest.raises(InjectedFault):
        plan.fire("s")
    plan.fire("s")                    # exhausted: silent
    assert plan.fired("s") == 1
    assert plan.specs("s")[0].seen == 2


def test_site_isolation():
    plan = FaultPlan([FaultSpec(site="a")])
    plan.fire("b")                    # wrong site: never matches
    assert plan.fired() == 0
    with pytest.raises(InjectedFault):
        plan.fire("a")


def test_after_skips_then_times_bounds():
    plan = FaultPlan([FaultSpec(site="s", after=2, times=2)])
    plan.fire("s")                    # skipped (1/2)
    plan.fire("s")                    # skipped (2/2)
    with pytest.raises(InjectedFault):
        plan.fire("s")                # firing 1
    with pytest.raises(InjectedFault):
        plan.fire("s")                # firing 2
    plan.fire("s")                    # exhausted
    assert plan.fired("s") == 2


def test_times_none_fires_forever():
    plan = FaultPlan([FaultSpec(site="s", times=None)])
    for _ in range(5):
        with pytest.raises(InjectedFault):
            plan.fire("s")
    assert plan.fired("s") == 5


def test_match_predicate_filters_context():
    plan = FaultPlan([FaultSpec(site="s", times=None,
                                match=lambda ctx: ctx["backend"] == "pallas")])
    plan.fire("s", backend="ref")
    with pytest.raises(InjectedFault):
        plan.fire("s", backend="pallas")
    # non-matching occurrences are not even counted as seen
    assert plan.specs("s")[0].seen == 1


def test_exception_instance_raised_as_is():
    boom = MemoryError("synthetic OOM")
    plan = FaultPlan([FaultSpec(site="s", exc=boom)])
    with pytest.raises(MemoryError) as ei:
        plan.fire("s")
    assert ei.value is boom


def test_exception_class_instantiated_with_context():
    plan = FaultPlan([FaultSpec(site="s", exc=RuntimeError)])
    with pytest.raises(RuntimeError, match="injected fault at 's'"):
        plan.fire("s")


def test_latency_only_spec_sleeps_without_raising():
    plan = FaultPlan([FaultSpec(site="s", exc=None, latency_s=0.05)])
    t0 = time.monotonic()
    plan.fire("s")                    # no raise
    assert time.monotonic() - t0 >= 0.04
    assert plan.fired("s") == 1


def test_probabilistic_firing_is_seed_deterministic():
    def pattern(seed):
        plan = FaultPlan([FaultSpec(site="s", times=None, p=0.5)],
                         seed=seed)
        out = []
        for _ in range(50):
            try:
                plan.fire("s")
                out.append(0)
            except InjectedFault:
                out.append(1)
        return out

    a, b = pattern(7), pattern(7)
    assert a == b                      # bit-for-bit replay
    assert 0 < sum(a) < 50             # genuinely probabilistic
    assert pattern(8) != a             # and seed-sensitive


def test_reset_rewinds_counters_rng_and_log():
    plan = FaultPlan([FaultSpec(site="s", times=1)], seed=3)
    with pytest.raises(InjectedFault):
        plan.fire("s", backend="pallas", op="gemm")
    assert plan.log and plan.log[0][0] == "s"
    plan.reset()
    assert plan.fired() == 0 and plan.log == []
    with pytest.raises(InjectedFault):   # fires again after the rewind
        plan.fire("s")


def test_log_keeps_only_scalar_context():
    plan = FaultPlan([FaultSpec(site="s")])
    with pytest.raises(InjectedFault):
        plan.fire("s", backend="pallas", n=4, dims=(32, 32, 32),
                  payload=object())
    (_, _, ctx), = plan.log
    assert ctx == {"backend": "pallas", "n": 4, "dims": (32, 32, 32)}


def test_first_matching_spec_wins_then_later_specs_take_over():
    plan = FaultPlan([FaultSpec(site="s", times=1, exc=KeyError),
                      FaultSpec(site="s", times=None, exc=ValueError)])
    with pytest.raises(KeyError):
        plan.fire("s")
    with pytest.raises(ValueError):    # first spec exhausted
        plan.fire("s")
    assert plan.fired("s") == 2


def test_concurrent_firing_counts_exactly():
    plan = FaultPlan([FaultSpec(site="s", times=None)])
    n_threads, per_thread = 8, 50

    def hammer():
        for _ in range(per_thread):
            try:
                plan.fire("s")
            except InjectedFault:
                pass

    threads = [threading.Thread(target=hammer) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert plan.fired("s") == n_threads * per_thread
    assert plan.specs("s")[0].seen == n_threads * per_thread
