"""End-to-end resilience tests: execution-time backend degradation, knob
quarantine (runtime + persistence round-trip), request deadlines, worker
supervision, close() abandonment semantics, eval-failure containment, and
the retuner's fault recovery + epsilon exploration."""

import time

import numpy as np
import pytest

from repro.backends import (get_backend, degradation_chain, resolve_backend,
                            reset_fallback_counts)
from repro.core import AdsalaRuntime, ModelRegistry, install_subroutine
from repro.core.knobs import Knob
from repro.kernels import ops
from repro.kernels.ops import run_op
from repro.serving import (BlasService, DeadlineExpiredError,
                           ExecutionFailedError, FaultPlan, FaultSpec,
                           InjectedFault, Retuner, RetuneConfig, ServeConfig,
                           ServiceClosedError)

OPS = ("gemm", "symm", "syrk", "syr2k", "trmm", "trsm")
DIMS = {"gemm": (16, 16, 16), "symm": (16, 16), "syrk": (16, 16),
        "syr2k": (16, 16), "trmm": (16, 16), "trsm": (16, 16)}


def make(op, dims, seed=0, dtype=np.float32):
    return get_backend("ref").make_operands(op, dims, dtype, seed=seed)


class FixedSub:
    """Stub subroutine whose model always selects one fixed knob."""

    def __init__(self, knob, backend="cpu_blocked", op="gemm",
                 dtype_bytes=4):
        self.backend = backend
        self.op = op
        self.dtype_bytes = dtype_bytes
        self.knob = knob
        self.artifact_version = 0

    def select(self, dims):
        return self.knob


def _cpu_knobs():
    """(default knob, one non-default knob) for cpu_blocked gemm."""
    be = get_backend("cpu_blocked")
    default = be.default_knob("gemm")
    space = be.knob_space("gemm")
    bad = next(c for c in space.candidates if c != default)
    return default, bad


@pytest.fixture(scope="module")
def tuned():
    """One real tuned artifact (flat-time timer keeps the install fast)."""
    space = ops.knob_space_for("gemm", sizes=(32, 64))
    return install_subroutine(
        "gemm", space, lambda dims, knob: 1e-3, n_samples=12,
        dim_lo=32, dim_hi=64, max_footprint_bytes=1_000_000,
        tune_trials=1, candidates=("LinearRegression",), use_lof=False,
        backend="pallas")


# ---------------------------------------------------------------------------
# degradation chain
# ---------------------------------------------------------------------------

def test_degradation_chain_shape():
    assert degradation_chain("pallas") == ("pallas", "cpu_blocked", "ref")
    assert degradation_chain("cpu_blocked") == ("cpu_blocked", "ref")
    # ref (and any name outside DEGRADE_ORDER) never degrades *up* onto an
    # accelerator path it did not ask for
    assert degradation_chain("ref") == ("ref",)
    assert degradation_chain("custom_plugin") == ("custom_plugin", "ref")


@pytest.mark.parametrize("backend", ["pallas", "cpu_blocked"])
@pytest.mark.parametrize("op", OPS)
def test_kernel_fault_degrades_to_ref_bit_identical(op, backend):
    """A kernel crash on every accelerator rung lands the bucket on ref,
    and the served results are bit-identical to a clean stacked ref run."""
    plan = FaultPlan([FaultSpec(site="kernel_execute", times=None,
                                match=lambda c: c["backend"] != "ref")])
    rt = AdsalaRuntime(faults=plan)
    cfg = ServeConfig(backend=backend, max_batch=4, linger_ms=1.0,
                      workers=1, min_steal=4, exec_retries=0,
                      retry_backoff_s=0.0)
    reqs = [make(op, DIMS[op], seed=i) for i in range(4)]
    with BlasService(runtime=rt, config=cfg, faults=plan) as svc:
        futs = [svc.submit(op, r) for r in reqs]
        outs = [np.asarray(f.result(timeout=60)) for f in futs]
    assert svc.stats.failed == 0 and svc.stats.completed == 4
    assert svc.stats.fallback_executions >= 1
    assert plan.fired("kernel_execute") >= 1
    # the accelerator rungs crashed BEFORE dispatch, so the degraded run is
    # the only execution — compare against a clean stacked ref call of the
    # exact same width (4 requests = full bucket, no padding)
    stacked = tuple(np.stack([r[i] for r in reqs])
                    for i in range(len(reqs[0])))
    clean = np.asarray(run_op(op, stacked, backend="ref", stacked=True))
    for i, out in enumerate(outs):
        assert np.array_equal(out, clean[i]), (op, backend, i)


def test_transient_crash_retries_same_backend():
    plan = FaultPlan([FaultSpec(site="stacked_execute", times=1)])
    cfg = ServeConfig(backend="ref", max_batch=2, linger_ms=1.0, workers=1,
                      min_steal=2, exec_retries=1, retry_backoff_s=0.0)
    with BlasService(runtime=AdsalaRuntime(), config=cfg,
                     faults=plan) as svc:
        futs = [svc.submit("gemm", make("gemm", (16, 16, 16), seed=i))
                for i in range(2)]
        for f in futs:
            f.result(timeout=60)
    assert svc.stats.retries == 1
    assert svc.stats.completed == 2
    assert svc.stats.fallback_executions == 0   # same-backend recovery


def test_chain_exhausted_raises_typed_with_cause():
    plan = FaultPlan([FaultSpec(site="stacked_execute", times=None)])
    cfg = ServeConfig(backend="ref", max_batch=1, linger_ms=1.0, workers=1,
                      exec_retries=0, retry_backoff_s=0.0)
    with BlasService(runtime=AdsalaRuntime(), config=cfg,
                     faults=plan) as svc:
        fut = svc.submit("gemm", make("gemm", (16, 16, 16)))
        with pytest.raises(ExecutionFailedError) as ei:
            fut.result(timeout=60)
    assert isinstance(ei.value.__cause__, InjectedFault)
    assert svc.stats.failed == 1 and svc.stats.completed == 0


def test_bisection_isolates_poisoned_stack():
    """A stack that fails as a whole but succeeds per-request is bisected
    down to singles; no batchmate is sunk."""
    plan = FaultPlan([FaultSpec(site="stacked_execute", times=None,
                                match=lambda c: c["n"] > 1)])
    cfg = ServeConfig(backend="ref", max_batch=4, linger_ms=1.0, workers=1,
                      min_steal=4, exec_retries=0, retry_backoff_s=0.0)
    reqs = [make("gemm", (16, 16, 16), seed=i) for i in range(4)]
    with BlasService(runtime=AdsalaRuntime(), config=cfg,
                     faults=plan) as svc:
        futs = [svc.submit("gemm", r) for r in reqs]
        outs = [np.asarray(f.result(timeout=60)) for f in futs]
    assert svc.stats.failed == 0 and svc.stats.completed == 4
    for r, out in zip(reqs, outs):
        want = np.asarray(run_op("gemm", (r[0][None], r[1][None]),
                                 backend="ref", stacked=True))[0]
        assert np.array_equal(out, want)


# ---------------------------------------------------------------------------
# knob quarantine
# ---------------------------------------------------------------------------

def test_poisoned_knob_is_quarantined_and_bucket_served():
    """A knob that crashes every attempt while the backend's default runs
    clean is pinned on the KNOB: quarantined, and the probe result serves
    the bucket on the same backend."""
    default, bad = _cpu_knobs()
    plan = FaultPlan([FaultSpec(site="kernel_execute", times=None,
                                match=lambda c: c.get("knob") == bad)])
    rt = AdsalaRuntime(faults=plan)
    rt.register(FixedSub(bad))
    cfg = ServeConfig(backend="cpu_blocked", max_batch=2, linger_ms=1.0,
                      workers=1, min_steal=2, exec_retries=0,
                      retry_backoff_s=0.0, quarantine_ttl_s=60.0)
    reqs = [make("gemm", (16, 16, 16), seed=i) for i in range(2)]
    with BlasService(runtime=rt, config=cfg, faults=plan) as svc:
        futs = [svc.submit("gemm", r) for r in reqs]
        outs = [np.asarray(f.result(timeout=60), np.float64) for f in futs]
    assert svc.stats.quarantined_knobs == 1
    assert svc.stats.failed == 0 and svc.stats.completed == 2
    assert svc.stats.fallback_executions == 0   # served on cpu_blocked
    assert rt.is_quarantined("gemm", 4, "cpu_blocked", bad)
    assert rt.stats.quarantines == 1
    # the poisoned cached decision was invalidated in the same stroke
    assert rt.peek("gemm", (16, 16, 16), 4, backend="cpu_blocked") is None
    # and subsequent selections are forced onto the fallback, uncached
    assert rt.select("gemm", (16, 16, 16), 4,
                     backend="cpu_blocked") == default
    assert rt.stats.quarantine_forced >= 1
    for r, out in zip(reqs, outs):
        want = np.asarray(r[0] @ r[1], np.float64)
        rel = np.max(np.abs(out - want)) / (np.max(np.abs(want)) + 1e-9)
        assert rel < 5e-4


def test_quarantine_ttl_half_opens():
    default, bad = _cpu_knobs()
    rt = AdsalaRuntime()
    rt.register(FixedSub(bad))
    dims = (32, 32, 32)
    assert rt.select("gemm", dims, 4, backend="cpu_blocked") == bad
    rt.quarantine_knob("gemm", 4, "cpu_blocked", bad, fallback=default,
                       ttl_s=0.15)
    # while open: forced to the fallback, never cached
    assert rt.select("gemm", dims, 4, backend="cpu_blocked") == default
    assert rt.peek("gemm", dims, 4, backend="cpu_blocked") is None
    # exploration must refuse the benched knob
    assert not rt.override_decision("gemm", dims, 4, "cpu_blocked", bad)
    time.sleep(0.2)
    # half-open: the model's own pick is served — and cached — again
    assert not rt.is_quarantined("gemm", 4, "cpu_blocked", bad)
    assert rt.select("gemm", dims, 4, backend="cpu_blocked") == bad
    assert rt.peek("gemm", dims, 4, backend="cpu_blocked") == bad


def test_quarantine_round_trips_through_cache_persistence():
    """export_cache/import_cache must carry active quarantines across a
    restart and never resurrect a benched decision entry."""
    default, bad = _cpu_knobs()
    dims = (32, 32, 32)
    rt1 = AdsalaRuntime()
    rt1.register(FixedSub(bad))
    assert rt1.select("gemm", dims, 4, backend="cpu_blocked") == bad
    poisoned_entries = rt1.export_cache()    # decision w/ bad, no breaker
    rt1.quarantine_knob("gemm", 4, "cpu_blocked", bad, fallback=default,
                        ttl_s=60.0)
    q_entries = rt1.export_cache()
    assert any(e.get("quarantine") for e in q_entries)
    rt2 = AdsalaRuntime()
    rt2.register(FixedSub(bad))
    rt2.import_cache(q_entries + poisoned_entries)
    assert rt2.is_quarantined("gemm", 4, "cpu_blocked", bad)
    assert rt2.stats.import_drops_quarantine == 1
    assert rt2.peek("gemm", dims, 4, backend="cpu_blocked") is None
    assert rt2.select("gemm", dims, 4, backend="cpu_blocked") == default


# ---------------------------------------------------------------------------
# deadlines / lifecycle
# ---------------------------------------------------------------------------

def test_deadline_expires_waiting_request_only():
    cfg = ServeConfig(backend="ref", max_batch=8, linger_ms=150.0,
                      workers=1, min_steal=8)
    operands = make("gemm", (16, 16, 16))
    with BlasService(runtime=AdsalaRuntime(), config=cfg) as svc:
        f_dead = svc.submit("gemm", operands, deadline=0.01)
        f_live = svc.submit("gemm", operands)
        with pytest.raises(DeadlineExpiredError):
            f_dead.result(timeout=60)
        f_live.result(timeout=60)
    assert svc.stats.deadline_expired == 1
    assert svc.stats.completed == 1 and svc.stats.failed == 0


def test_deadline_validation():
    with BlasService(runtime=AdsalaRuntime(),
                     config=ServeConfig(backend="ref", workers=1)) as svc:
        with pytest.raises(ValueError):
            svc.submit("gemm", make("gemm", (16, 16, 16)), deadline=0)


def test_submit_after_close_raises_service_closed():
    svc = BlasService(runtime=AdsalaRuntime(),
                      config=ServeConfig(backend="ref", workers=1))
    svc.close()
    with pytest.raises(ServiceClosedError):
        svc.submit("gemm", make("gemm", (16, 16, 16)))
    svc.close()                       # idempotent


def test_close_fails_stuck_requests_instead_of_leaking():
    """A request stuck behind a hung backend past the close timeout is
    FAILED with ServiceClosedError — its caller never blocks forever."""
    plan = FaultPlan([FaultSpec(site="stacked_execute", exc=None,
                                latency_s=1.5)])
    cfg = ServeConfig(backend="ref", max_batch=1, linger_ms=1.0, workers=1)
    svc = BlasService(runtime=AdsalaRuntime(), config=cfg, faults=plan)
    fut = svc.submit("gemm", make("gemm", (16, 16, 16)))
    time.sleep(0.3)                   # let the worker claim and stall
    svc.close(timeout=0.2)
    with pytest.raises(ServiceClosedError):
        fut.result(timeout=1.0)
    assert svc.stats.failed == 1
    # let the stalled worker wake and exit before the interpreter tears
    # down (its late resolution must also be a harmless no-op)
    for w in svc._workers:
        w.join(timeout=5.0)
    assert svc.stats.completed == 0


def test_worker_death_recovers_without_request_loss():
    plan = FaultPlan([FaultSpec(site="worker", times=1)])
    cfg = ServeConfig(backend="ref", max_batch=4, linger_ms=1.0, workers=2,
                      min_steal=4)
    reqs = [make("gemm", (16, 16, 16), seed=i) for i in range(4)]
    with BlasService(runtime=AdsalaRuntime(), config=cfg,
                     faults=plan) as svc:
        futs = [svc.submit("gemm", r) for r in reqs]
        outs = [np.asarray(f.result(timeout=60), np.float64) for f in futs]
    assert plan.fired("worker") == 1
    assert svc.stats.worker_respawns >= 1
    assert svc.stats.completed == 4 and svc.stats.failed == 0
    for r, out in zip(reqs, outs):
        want = np.asarray(r[0] @ r[1], np.float64)
        rel = np.max(np.abs(out - want)) / (np.max(np.abs(want)) + 1e-9)
        assert rel < 5e-4


def test_worker_death_storm_fails_bucket_typed():
    """A bucket that kills every worker that claims it is failed after a
    bounded number of recoveries instead of crash-looping the pool."""
    plan = FaultPlan([FaultSpec(site="worker", times=None)])
    cfg = ServeConfig(backend="ref", max_batch=1, linger_ms=1.0, workers=1)
    with BlasService(runtime=AdsalaRuntime(), config=cfg,
                     faults=plan) as svc:
        fut = svc.submit("gemm", make("gemm", (16, 16, 16)))
        with pytest.raises(ExecutionFailedError, match="killed"):
            fut.result(timeout=60)
    assert svc.stats.worker_respawns >= 4
    assert svc.stats.failed == 1


# ---------------------------------------------------------------------------
# eval-failure containment / resolve fallback accounting
# ---------------------------------------------------------------------------

def test_eval_failure_serves_default_knob():
    default, bad = _cpu_knobs()
    plan = FaultPlan([FaultSpec(site="predictor_eval", times=None)])
    rt = AdsalaRuntime(faults=plan)
    rt.register(FixedSub(bad))
    got = rt.select_or_default("gemm", (32, 32, 32), 4, default,
                               backend="cpu_blocked")
    assert got == default
    assert rt.stats.eval_failures == 1
    assert rt.stats.default_calls == 1
    # a bare select() propagates — callers without a fallback must see it
    with pytest.raises(InjectedFault):
        rt.select("gemm", (48, 48, 48), 4, backend="cpu_blocked")


def test_select_many_isolates_failing_groups():
    default, bad = _cpu_knobs()
    plan = FaultPlan([FaultSpec(site="predictor_eval", times=None,
                                match=lambda c: c["op"] == "gemm")])
    rt = AdsalaRuntime(faults=plan)
    rt.register(FixedSub(bad))
    rt.register(FixedSub(bad, op="syrk"))
    out = rt.select_many([("gemm", (32, 32, 32), 4, "cpu_blocked"),
                          ("syrk", (32, 32), 4, "cpu_blocked")])
    assert out[0] is None             # failed group left untuned
    assert out[1] == bad              # healthy group still selected
    assert rt.stats.eval_failures >= 1


def test_resolve_fallbacks_surface_in_runtime_stats():
    reset_fallback_counts()
    assert resolve_backend("no_such_backend_xyz").name == "ref"
    counts = AdsalaRuntime().stats.resolve_fallbacks
    assert counts[("no_such_backend_xyz", "ref")] >= 1
    reset_fallback_counts()


# ---------------------------------------------------------------------------
# artifact-load fault isolation
# ---------------------------------------------------------------------------

def test_artifact_load_faults_are_isolated(tmp_path, tuned):
    plan = FaultPlan([FaultSpec(site="artifact_load", times=1)])
    reg = ModelRegistry(tmp_path, faults=plan)
    reg.save(tuned)
    (tmp_path / "pallas__zzz_b4.adsala").write_bytes(b"not msgpack")
    rt = AdsalaRuntime()
    # first hydration: the good artifact's load is fault-injected AND the
    # junk file fails to unpack — both recorded, neither aborts the scan
    assert reg.load_into(rt) == 0
    assert len(reg.last_load_errors) == 2
    assert not rt.has("gemm", 4, "pallas")
    # fault exhausted: the good artifact now loads, junk is still skipped
    assert reg.load_into(rt) == 1
    assert len(reg.last_load_errors) == 1
    assert "zzz" in reg.last_load_errors[0][0]
    assert rt.has("gemm", 4, "pallas")


# ---------------------------------------------------------------------------
# retuner: fault recovery + epsilon exploration
# ---------------------------------------------------------------------------

def test_retuner_survives_observe_faults():
    plan = FaultPlan([FaultSpec(site="retuner_observe", times=1)])
    r = Retuner(AdsalaRuntime(), faults=plan)
    assert r.step() == []
    assert r.stats.observe_failures == 1
    assert r.step() == []             # recovered
    assert r.stats.observe_failures == 1


def test_retuner_survives_refit_faults(tuned):
    from repro.serving.retune import _SubState
    plan = FaultPlan([FaultSpec(site="retuner_refit", times=None)])
    rt = AdsalaRuntime()
    rt.register(tuned)
    r = Retuner(rt, faults=plan)
    st = _SubState(cap=16)
    st.ewma, st.n = 10.0, 8           # force the drift trigger
    st.put((32, 32, 32), 0, 1.0)
    r._state[("pallas", "gemm", 4)] = st
    assert r.step() == []             # refit raised, old model kept serving
    assert r.stats.refit_failures == 1 and r.stats.errors == 1
    assert r.stats.retunes == 0
    assert "InjectedFault" in r.stats.last_error


def test_exploration_overrides_one_bucket_then_restores(tuned):
    rt = AdsalaRuntime()
    rt.register(tuned)
    dims = (32, 32, 32)
    base = rt.select("gemm", dims, 4, backend="pallas")
    rt.record_batch("gemm", dims, 4, "pallas", 4, exec_seconds=4e-3,
                    exec_items=4)
    r = Retuner(rt, config=RetuneConfig(explore_epsilon=0.9, seed=0))
    fired = 0
    for _ in range(25):               # seeded Bernoulli: bounded retry
        fired = r._explore()
        if fired:
            break
    assert fired == 1 and r.stats.explorations == 1
    explored = rt.peek("gemm", dims, 4, backend="pallas")
    assert explored is not None and explored != base
    assert explored in tuned.knob_space.candidates
    # the next pass restores the override BEFORE (maybe) placing a new one:
    # the served knob is never a stale override
    r._explore()
    cur = rt.peek("gemm", dims, 4, backend="pallas")
    if r._exploring:
        assert cur == next(iter(r._exploring.values()))
    else:
        assert cur is None            # restored: next select re-runs model
        assert rt.select("gemm", dims, 4, backend="pallas") == base


def test_exploration_excludes_quarantined_knobs(tuned):
    rt = AdsalaRuntime()
    rt.register(tuned)
    dims = (32, 32, 32)
    base = rt.select("gemm", dims, 4, backend="pallas")
    rt.record_batch("gemm", dims, 4, "pallas", 1, exec_seconds=1e-3,
                    exec_items=1)
    for cand in tuned.knob_space.candidates:
        if cand != base:
            rt.quarantine_knob("gemm", 4, "pallas", cand, fallback=base,
                               ttl_s=60.0)
    r = Retuner(rt, config=RetuneConfig(explore_epsilon=0.9, seed=1))
    assert sum(r._explore() for _ in range(20)) == 0
    assert r.stats.explorations == 0


def test_config_validation_new_fields():
    with pytest.raises(ValueError):
        ServeConfig(exec_retries=-1)
    with pytest.raises(ValueError):
        ServeConfig(retry_backoff_s=-0.1)
    with pytest.raises(ValueError):
        ServeConfig(quarantine_ttl_s=0.0)
    with pytest.raises(ValueError):
        RetuneConfig(explore_epsilon=1.0)
    with pytest.raises(ValueError):
        RetuneConfig(explore_epsilon=-0.1)
