"""Parametrized cross-backend conformance suite: every registered backend ×
all six BLAS L3 ops × both dtypes, checked against the float64 numpy oracle
with a per-dtype tolerance (``scripts/check_backends.py`` is a thin CLI
wrapper over the same harness in ``repro.backends.conformance``)."""

import numpy as np
import pytest

from repro.backends import L3_OPS, available_backends, get_backend
from repro.backends.conformance import (DEFAULT_DIMS, RAGGED_DIMS,
                                        check_backend_op, oracle,
                                        tolerance_for)

BACKENDS = available_backends()
DTYPES = pytest.mark.parametrize(
    "dtype", (np.float32, np.float64), ids=("f32", "f64"))


def _gate(backend, op, dtype):
    be = get_backend(backend)
    if not be.is_available():
        pytest.skip(f"{backend} unavailable on host")
    if not be.supports_dtype(dtype):
        pytest.skip(f"{backend} does not execute {np.dtype(dtype).name} "
                    f"at full precision")
    return be


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("op", L3_OPS)
@DTYPES
def test_matches_oracle(backend, op, dtype):
    _gate(backend, op, dtype)
    res = check_backend_op(backend, op, dtype, seed=7)
    assert res.skipped is None, res.line()
    assert res.error is None, res.line()
    assert res.ok, res.line()


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("op", L3_OPS)
def test_stacked_matches_oracle(backend, op):
    """execute_stacked over a width-3 stack of distinct problems equals
    three independent oracle calls (the serving layer's batch primitive)."""
    _gate(backend, op, np.float32)
    res = check_backend_op(backend, op, np.float32, stacked=3, seed=11)
    assert res.skipped is None and res.error is None, res.line()
    assert res.ok, res.line()


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("op", L3_OPS)
@pytest.mark.parametrize("ragged_idx", (0, 1, 2),
                         ids=("ragged-tail", "one-row", "off-square"))
@DTYPES
def test_ragged_matches_oracle(backend, op, ragged_idx, dtype):
    """Non-block-multiple dims across every op × backend: a ragged last
    tile behind full tiles, a single-row problem, and an off-multiple
    square — the masked edge tiles of the zero-copy kernels at their
    corners."""
    _gate(backend, op, dtype)
    dims = RAGGED_DIMS[op][ragged_idx]
    res = check_backend_op(backend, op, dtype, dims=dims, seed=17)
    assert res.skipped is None and res.error is None, res.line()
    assert res.ok, res.line()


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("op", L3_OPS)
def test_ragged_stacked_matches_oracle(backend, op):
    """The stacked (leading-batch-grid) path at ragged dims — a width-2
    stack of distinct ragged problems equals two oracle calls."""
    _gate(backend, op, np.float32)
    res = check_backend_op(backend, op, np.float32,
                           dims=RAGGED_DIMS[op][0], stacked=2, seed=23)
    assert res.skipped is None and res.error is None, res.line()
    assert res.ok, res.line()


@pytest.mark.parametrize("op", L3_OPS)
def test_oracle_self_consistent(op):
    """The numpy oracle agrees with the repo's jnp reference kernels — the
    two independent statements of Table-I semantics cross-check each other."""
    from repro.kernels import ref
    be = get_backend("ref")
    operands = be.make_operands(op, DEFAULT_DIMS[op], np.float32, seed=3)
    want = oracle(op, operands)
    got = np.asarray(ref.REFS[op](*[np.asarray(x) for x in operands]),
                     np.float64)
    rel = np.max(np.abs(got - want)) / (np.max(np.abs(want)) + 1e-9)
    assert rel < tolerance_for(np.float32)


def test_tolerances_are_per_dtype():
    assert tolerance_for(np.float64) < tolerance_for(np.float32)
