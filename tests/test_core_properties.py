"""Property-based tests (hypothesis) for the ADSALA core invariants."""

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core.halton import halton_sequence, sample_dims, scrambled_halton
from repro.core.preprocess import (CorrelationPruner, PreprocessPipeline,
                                   StandardScaler, YeoJohnsonTransformer,
                                   yeo_johnson, yeo_johnson_inverse)
from repro.core.lof import lof_scores, remove_outliers
from repro.core.features import (SUBROUTINES, build_features, feature_names,
                                 footprint_words, SUBROUTINE_NDIMS)
from repro.core.split import stratified_split


# ---------------------------------------------------------------------------
# Halton
# ---------------------------------------------------------------------------

@given(st.integers(10, 300), st.integers(0, 5))
@settings(max_examples=20, deadline=None)
def test_scrambled_halton_in_unit_interval(n, seed):
    pts = scrambled_halton(n, (2, 3, 4), seed=seed)
    assert pts.shape == (n, 3)
    assert np.all(pts > 0) and np.all(pts < 1)


def test_scrambled_halton_deterministic():
    a = scrambled_halton(100, (2, 3), seed=7)
    b = scrambled_halton(100, (2, 3), seed=7)
    np.testing.assert_array_equal(a, b)
    c = scrambled_halton(100, (2, 3), seed=8)
    assert not np.array_equal(a, c)


def test_halton_low_discrepancy_vs_iid_worst_case():
    """Star-discrepancy proxy: max deviation of empirical CDF on a grid —
    Halton should beat the iid-uniform upper tail comfortably."""
    n = 512
    pts = scrambled_halton(n, (2, 3), seed=0)
    grid = np.linspace(0.1, 0.9, 9)
    worst = 0.0
    for gx in grid:
        for gy in grid:
            emp = np.mean((pts[:, 0] < gx) & (pts[:, 1] < gy))
            worst = max(worst, abs(emp - gx * gy))
    assert worst < 0.05, worst


def test_sample_dims_respects_footprint_cap():
    cap = 64 * 1024
    fp = lambda d: footprint_words("gemm", d) * 4
    dims = sample_dims(50, 3, lo=16, hi=512, max_footprint_bytes=cap,
                       footprint_fn=fp, seed=1)
    assert all(fp(tuple(d)) <= cap for d in dims)
    assert dims.min() >= 1


# ---------------------------------------------------------------------------
# Yeo-Johnson
# ---------------------------------------------------------------------------

@given(st.floats(-2.5, 2.5), st.lists(st.floats(-50, 50), min_size=3,
                                      max_size=50))
@settings(max_examples=60, deadline=None)
def test_yeo_johnson_invertible_and_monotone(lmbda, xs):
    x = np.asarray(xs)
    y = yeo_johnson(x, lmbda)
    back = yeo_johnson_inverse(y, lmbda)
    np.testing.assert_allclose(back, x, rtol=1e-6, atol=1e-6)
    order = np.argsort(x, kind="stable")
    assert np.all(np.diff(y[order]) >= -1e-9)   # monotone


def test_yeo_johnson_mle_gaussianizes_lognormal():
    rng = np.random.default_rng(0)
    x = rng.lognormal(0, 1, size=(800, 1))
    t = YeoJohnsonTransformer().fit(x)
    z = t.transform(x)[:, 0]
    skew_before = float(np.mean(((x[:, 0] - x.mean()) / x.std()) ** 3))
    skew_after = float(np.mean(((z - z.mean()) / z.std()) ** 3))
    assert abs(skew_after) < abs(skew_before) / 3


# ---------------------------------------------------------------------------
# scaler / pruner / pipeline
# ---------------------------------------------------------------------------

def test_standard_scaler_roundtrip_stats():
    rng = np.random.default_rng(1)
    X = rng.normal(3.0, 7.0, size=(500, 4))
    Z = StandardScaler().fit_transform(X)
    np.testing.assert_allclose(Z.mean(axis=0), 0, atol=1e-9)
    np.testing.assert_allclose(Z.std(axis=0), 1, atol=1e-9)


def test_correlation_pruner_drops_duplicate_feature():
    rng = np.random.default_rng(2)
    a = rng.normal(size=500)
    b = rng.normal(size=500)
    X = np.stack([a, b, a * 1.0001 + 1e-6 * rng.normal(size=500)], axis=1)
    pr = CorrelationPruner(0.8).fit(X)
    kept = set(pr.keep_.tolist())
    assert len(kept) == 2 and 1 in kept
    assert not {0, 2} <= kept          # one of the correlated pair dropped


def test_pipeline_state_roundtrip():
    rng = np.random.default_rng(3)
    X = np.abs(rng.lognormal(size=(200, 5)))
    p1 = PreprocessPipeline()
    Z1 = p1.fit_transform(X)
    p2 = PreprocessPipeline()
    p2.set_state(p1.get_state())
    np.testing.assert_allclose(p2.transform(X), Z1, rtol=1e-10)


# ---------------------------------------------------------------------------
# LOF
# ---------------------------------------------------------------------------

def test_lof_flags_planted_outlier():
    rng = np.random.default_rng(4)
    X = rng.normal(size=(300, 3))
    X[0] = [25.0, -25.0, 25.0]          # gross outlier
    scores = lof_scores(X, k=20)
    assert scores[0] > np.percentile(scores[1:], 99)


def test_remove_outliers_keeps_at_least_90pct():
    rng = np.random.default_rng(5)
    X = rng.normal(size=(200, 4))
    y = rng.normal(size=200)
    _, _, keep = remove_outliers(X, y)
    assert keep.sum() >= 0.9 * len(keep) - 1


# ---------------------------------------------------------------------------
# features
# ---------------------------------------------------------------------------

@given(st.sampled_from(SUBROUTINES), st.integers(1, 2048), st.integers(1, 2048),
       st.integers(1, 2048), st.integers(1, 64))
@settings(max_examples=60, deadline=None)
def test_feature_table_iii_identities(op, m, k, n, nt):
    ndims = SUBROUTINE_NDIMS[op]
    dims = np.array([[m, k, n][:ndims]])
    X = build_features(op, dims, np.array([nt]))
    names = feature_names(ndims)
    assert X.shape == (1, len(names))
    row = dict(zip(names, X[0]))
    if ndims == 3:
        assert row["m*k*n"] == pytest.approx(m * k * n)
        assert row["m*k*n/nt"] == pytest.approx(m * k * n / nt)
        assert row["footprint"] == pytest.approx(m * k + k * n + m * n)
    else:
        assert row["m*n"] == pytest.approx(m * k)   # dims = (m, k) here
        assert row["m/nt"] == pytest.approx(m / nt)
    assert np.all(np.isfinite(X))


def test_footprint_overwrite_rule():
    # TRMM/TRSM overwrite B: footprint counts B once (paper footnote 1)
    assert footprint_words("trmm", (100, 50)) == 100 * 100 + 100 * 50
    assert footprint_words("syr2k", (64, 32)) == 2 * 64 * 32 + 64 * 64


# ---------------------------------------------------------------------------
# stratified split
# ---------------------------------------------------------------------------

@given(st.integers(30, 500), st.integers(0, 3))
@settings(max_examples=20, deadline=None)
def test_stratified_split_partition(n, seed):
    rng = np.random.default_rng(seed)
    y = rng.lognormal(size=n)
    tr, te = stratified_split(y, test_frac=0.15, seed=seed)
    assert len(set(tr) & set(te)) == 0
    assert len(tr) + len(te) == n
    assert 0 < len(te) <= max(1, int(0.25 * n))
