"""Error-budget ledger (``repro.serving.budget``) and admission control:
breaker state machine with injectable time, ladder-level rung skipping and
half-open probes, budget persistence through export/import, priority and
deadline shedding at submit, brownout's zero-eval serving, and
deadline-bounded retry backoff."""

import time

import numpy as np
import pytest

from repro.backends import get_backend
from repro.core import AdsalaRuntime
from repro.core.knobs import Knob
from repro.serving import (AdmissionRejectedError, BlasService, BudgetConfig,
                           DeadlineExpiredError, ErrorBudgetLedger,
                           FaultPlan, FaultSpec, ServeConfig)


class StubSub:
    def __init__(self, backend: str = "ref", op: str = "gemm",
                 dtype_bytes: int = 4, knob=None) -> None:
        self.backend, self.op, self.dtype_bytes = backend, op, dtype_bytes
        self.knob = knob if knob is not None \
            else get_backend(backend).default_knob(op)
        self.artifact_version = 0
        self.evals = 0

    def select(self, dims):
        self.evals += 1
        return self.knob


def make(op, dims, seed=0):
    return get_backend("ref").make_operands(op, dims, np.float32, seed=seed)


CFG = BudgetConfig(window=8, threshold=0.5, min_count=3,
                   probe_interval_s=10.0)


# ---------------------------------------------------------------------------
# ledger state machine (injectable now: no sleeps, fully deterministic)
# ---------------------------------------------------------------------------

def test_budget_config_validation():
    with pytest.raises(ValueError, match="window"):
        BudgetConfig(window=0)
    with pytest.raises(ValueError, match="threshold"):
        BudgetConfig(threshold=1.5)
    with pytest.raises(ValueError, match="min_count"):
        BudgetConfig(min_count=0)
    with pytest.raises(ValueError, match="probe_interval_s"):
        BudgetConfig(probe_interval_s=0.0)


def test_ledger_unknown_rung_is_innocent():
    led = ErrorBudgetLedger(CFG)
    assert led.admit("pallas", "gemm", now=0.0) == "closed"
    assert led.snapshot() == {}


def test_ledger_opens_after_min_count_failures():
    led = ErrorBudgetLedger(CFG)
    led.record("b", "gemm", False, now=0.0)
    led.record("b", "gemm", False, now=0.0)
    # two failures < min_count: still within budget
    assert led.admit("b", "gemm", now=0.0) == "closed"
    led.record("b", "gemm", False, now=0.0)
    assert led.admit("b", "gemm", now=1.0) == "skip"      # opens here
    assert led.admit("b", "gemm", now=2.0) == "skip"      # stays open
    snap = led.snapshot()[("b", "gemm")]
    assert snap["state"] == "open" and snap["opens"] == 1
    assert snap["skips"] == 2 and snap["failure_rate"] == 1.0


def test_ledger_mixed_outcomes_below_threshold_stay_closed():
    led = ErrorBudgetLedger(CFG)
    for ok in (True, False, True, False, True, True):     # rate 1/3
        led.record("b", "gemm", ok, now=0.0)
    assert led.admit("b", "gemm", now=1.0) == "closed"


def test_ledger_probe_success_closes_and_forgives():
    led = ErrorBudgetLedger(CFG)
    for _ in range(3):
        led.record("b", "gemm", False, now=0.0)
    assert led.admit("b", "gemm", now=0.0) == "skip"
    # before the interval: still skipped; at the interval: one probe
    assert led.admit("b", "gemm", now=9.9) == "skip"
    assert led.admit("b", "gemm", now=10.0) == "probe"
    # probe outstanding: concurrent buckets are still skipped
    assert led.admit("b", "gemm", now=10.1) == "skip"
    led.record("b", "gemm", True, now=10.2)
    assert led.admit("b", "gemm", now=10.3) == "closed"
    # the window was forgiven: old failures don't instantly re-open
    snap = led.snapshot()[("b", "gemm")]
    assert snap["state"] == "closed" and snap["failure_rate"] == 0.0


def test_ledger_probe_failure_reopens():
    led = ErrorBudgetLedger(CFG)
    for _ in range(3):
        led.record("b", "gemm", False, now=0.0)
    assert led.admit("b", "gemm", now=0.0) == "skip"
    assert led.admit("b", "gemm", now=10.0) == "probe"
    led.record("b", "gemm", False, now=10.1)
    assert led.admit("b", "gemm", now=15.0) == "skip"      # re-opened
    assert led.admit("b", "gemm", now=20.1) == "probe"     # next interval


def test_ledger_reclaims_abandoned_probe():
    """A probe whose owner died without recording must not wedge the rung
    half-open forever — after a full interval the probe is re-issued."""
    led = ErrorBudgetLedger(CFG)
    for _ in range(3):
        led.record("b", "gemm", False, now=0.0)
    assert led.admit("b", "gemm", now=0.0) == "skip"
    assert led.admit("b", "gemm", now=10.0) == "probe"     # owner dies here
    assert led.admit("b", "gemm", now=15.0) == "skip"
    assert led.admit("b", "gemm", now=20.0) == "probe"     # reclaimed


def test_ledger_export_import_rebases_probe_clock():
    led = ErrorBudgetLedger(CFG)
    for _ in range(3):
        led.record("b", "gemm", False, now=0.0)
    assert led.admit("b", "gemm", now=0.0) == "skip"
    recs = led.export(now=4.0)          # 6s of the 10s interval remain
    assert recs == [{"budget": 1, "backend": "b", "op": "gemm",
                     "outcomes": [0, 0, 0], "state": "open",
                     "probe_in_s": 6.0}]
    # the restored breaker's probe comes due probe_in_s from the NEW now —
    # the dead process's monotonic clock never leaks across the restart
    led2 = ErrorBudgetLedger(CFG)
    assert led2.import_records(recs, now=1000.0) == 1
    assert led2.admit("b", "gemm", now=1005.9) == "skip"
    assert led2.admit("b", "gemm", now=1006.0) == "probe"


def test_ledger_import_tolerates_garbage():
    led = ErrorBudgetLedger(CFG)
    recs = [{"budget": 1},                        # missing fields
            {"budget": 1, "backend": "b", "op": "gemm",
             "outcomes": "xx", "state": "open"},  # bad outcomes
            {"budget": 1, "backend": "b", "op": "gemm",
             "outcomes": [1], "state": "weird"},  # unknown state
            {"not-budget": 1},
            {"budget": 1, "backend": "c", "op": "gemm",
             "outcomes": [0, 0, 0], "state": "closed"}]
    assert led.import_records(recs, now=0.0) == 1
    assert ("c", "gemm") in led.snapshot()


def test_half_open_exports_as_probe_due_now():
    led = ErrorBudgetLedger(CFG)
    for _ in range(3):
        led.record("b", "gemm", False, now=0.0)
    assert led.admit("b", "gemm", now=0.0) == "skip"
    assert led.admit("b", "gemm", now=10.0) == "probe"     # now half-open
    (rec,) = led.export(now=10.1)
    assert rec["state"] == "open" and rec["probe_in_s"] == 0.0


# ---------------------------------------------------------------------------
# the ladder honours the ledger
# ---------------------------------------------------------------------------

def _dead_rung_cfg(**kw):
    base = dict(backend="cpu_blocked", max_batch=1, linger_ms=0.5, workers=1,
                min_steal=1, exec_retries=1, retry_backoff_s=0.0,
                budget_window=8, budget_threshold=0.4, budget_min_count=2,
                budget_probe_interval_s=60.0)
    base.update(kw)
    return ServeConfig(**base)


def _dead_rung_plan(times=None):
    return FaultPlan([FaultSpec(site="kernel_execute", times=times,
                                match=lambda c:
                                c["backend"] == "cpu_blocked")])


def test_ladder_skips_over_budget_rung():
    plan = _dead_rung_plan()
    rt = AdsalaRuntime(faults=plan)
    with BlasService(runtime=rt, config=_dead_rung_cfg(),
                     faults=plan) as svc:
        svc.call("gemm", make("gemm", (16, 16, 16)))      # warmup: 2 attempts
        fired = plan.fired("kernel_execute")
        assert fired == 2
        for i in range(3):                                 # all skipped
            svc.call("gemm", make("gemm", (16, 16, 16), seed=i + 1))
        assert plan.fired("kernel_execute") == fired       # ZERO new attempts
        assert svc.stats.budget_skips == 3
        assert svc.stats.failed == 0                       # ref still serves
        state = svc.budget_state()[("cpu_blocked", "gemm")]
        assert state["state"] == "open"


def test_ladder_keeps_retrying_with_budgets_disabled():
    plan = _dead_rung_plan()
    rt = AdsalaRuntime(faults=plan)
    with BlasService(runtime=rt, config=_dead_rung_cfg(error_budget=False),
                     faults=plan) as svc:
        for i in range(3):
            svc.call("gemm", make("gemm", (16, 16, 16), seed=i))
        assert plan.fired("kernel_execute") == 6           # 2 per bucket
        assert svc.stats.budget_skips == 0
        assert svc.budget_state() == {}


def test_ladder_probe_closes_healed_rung():
    plan = _dead_rung_plan(times=2)     # fault dies with the warmup bucket
    rt = AdsalaRuntime(faults=plan)
    cfg = _dead_rung_cfg(budget_probe_interval_s=0.2)
    with BlasService(runtime=rt, config=cfg, faults=plan) as svc:
        svc.call("gemm", make("gemm", (16, 16, 16)))       # opens the breaker
        svc.call("gemm", make("gemm", (16, 16, 16), seed=1))   # skipped
        assert svc.stats.budget_skips >= 1
        fallbacks = svc.stats.fallback_executions
        time.sleep(0.25)
        svc.call("gemm", make("gemm", (16, 16, 16), seed=2))   # the probe
        assert svc.stats.budget_probes == 1
        # served on the primary rung again — no new fallback execution
        assert svc.stats.fallback_executions == fallbacks
        assert svc.budget_state()[("cpu_blocked", "gemm")]["state"] \
            == "closed"


def test_budget_state_survives_export_import():
    """A rung that exhausted its budget stays skipped across a warm
    restart: the ledger's records ride export_cache/import_cache."""
    plan = _dead_rung_plan()
    rt = AdsalaRuntime(faults=plan)
    with BlasService(runtime=rt, config=_dead_rung_cfg(),
                     faults=plan) as svc:
        svc.call("gemm", make("gemm", (16, 16, 16)))
        svc.call("gemm", make("gemm", (16, 16, 16), seed=1))
        assert svc.budget_state()[("cpu_blocked", "gemm")]["state"] == "open"
        exported = rt.export_cache()
    assert any(e.get("budget") for e in exported)

    # records imported BEFORE any service exists are parked, then drained
    # into the ledger the next service attaches
    rt2 = AdsalaRuntime()
    rt2.import_cache(exported)
    plan2 = _dead_rung_plan()
    with BlasService(runtime=rt2, config=_dead_rung_cfg(),
                     faults=plan2) as svc2:
        assert svc2.budget_state()[("cpu_blocked", "gemm")]["state"] \
            == "open"
        svc2.call("gemm", make("gemm", (16, 16, 16)))
        assert plan2.fired("kernel_execute") == 0          # still skipped
        assert svc2.stats.budget_skips == 1


def test_serve_config_budget_validation():
    with pytest.raises(ValueError, match="budget_window"):
        ServeConfig(budget_window=0)
    with pytest.raises(ValueError, match="budget_threshold"):
        ServeConfig(budget_threshold=0.0)
    with pytest.raises(ValueError, match="budget_min_count"):
        ServeConfig(budget_min_count=0)
    with pytest.raises(ValueError, match="budget_probe_interval_s"):
        ServeConfig(budget_probe_interval_s=-1.0)
    with pytest.raises(ValueError, match="shed_batch_at"):
        ServeConfig(shed_batch_at=1.5)
    with pytest.raises(ValueError, match="shed_explore_at"):
        ServeConfig(shed_explore_at=-0.1)
    with pytest.raises(ValueError, match="brownout_pending"):
        ServeConfig(brownout_pending=0)


# ---------------------------------------------------------------------------
# admission control: shed at submit, not in the queue
# ---------------------------------------------------------------------------

def test_priority_sheds_before_user_traffic():
    # one worker held by an injected latency while user traffic fills the
    # buffer past both shed thresholds (2 and 4 of max_pending=8)
    plan = FaultPlan([FaultSpec(site="stacked_execute", exc=None,
                                latency_s=0.25, times=None)])
    cfg = ServeConfig(backend="ref", max_batch=1, linger_ms=0.5, workers=1,
                      min_steal=1, max_pending=8, shed_explore_at=0.25,
                      shed_batch_at=0.5)
    with BlasService(runtime=AdsalaRuntime(), config=cfg,
                     faults=plan) as svc:
        futs = [svc.submit("gemm", make("gemm", (16, 16, 16), seed=i))
                for i in range(4)]
        with pytest.raises(AdmissionRejectedError, match="exploration"):
            svc.submit("gemm", make("gemm", (16, 16, 16)),
                       priority="exploration")
        with pytest.raises(AdmissionRejectedError, match="batch"):
            svc.submit("gemm", make("gemm", (16, 16, 16)), priority="batch")
        # user traffic is still admitted at the same depth
        futs.append(svc.submit("gemm", make("gemm", (16, 16, 16), seed=9)))
        for f in futs:
            f.result(timeout=120)
        assert svc.stats.shed_priority == 2
        assert svc.stats.failed == 0


def test_unknown_priority_rejected():
    cfg = ServeConfig(backend="ref", workers=1)
    with BlasService(runtime=AdsalaRuntime(), config=cfg) as svc:
        with pytest.raises(ValueError, match="priority"):
            svc.submit("gemm", make("gemm", (16, 16, 16)), priority="vip")


def test_deadline_infeasible_request_shed_at_submit():
    rt = AdsalaRuntime()
    # the bucket's observed mean queue delay says 0.5s
    rt.record_batch("gemm", (16, 16, 16), 4, "ref", 1,
                    queue_seconds=0.5, exec_items=1)
    cfg = ServeConfig(backend="ref", max_batch=1, linger_ms=0.5, workers=1,
                      min_steal=1)
    with BlasService(runtime=rt, config=cfg) as svc:
        with pytest.raises(AdmissionRejectedError, match="infeasible"):
            svc.submit("gemm", make("gemm", (16, 16, 16)), deadline=0.05)
        assert svc.stats.shed_deadline == 1
        # a feasible deadline on the same bucket is admitted and served
        out = svc.submit("gemm", make("gemm", (16, 16, 16)),
                         deadline=30.0).result(timeout=120)
        assert out is not None
        # shapes with NO history are never shed (no evidence: admit)
        svc.submit("gemm", make("gemm", (32, 32, 32)),
                   deadline=0.05).result(timeout=120)


def test_admission_control_off_admits_everything():
    rt = AdsalaRuntime()
    rt.record_batch("gemm", (16, 16, 16), 4, "ref", 1,
                    queue_seconds=0.5, exec_items=1)
    cfg = ServeConfig(backend="ref", max_batch=1, linger_ms=0.5, workers=1,
                      min_steal=1, admission_control=False)
    with BlasService(runtime=rt, config=cfg) as svc:
        # would be shed with admission control on; now merely deadlined
        f = svc.submit("gemm", make("gemm", (16, 16, 16)), deadline=10.0)
        f.result(timeout=120)
        assert svc.stats.shed_deadline == 0


def test_brownout_serves_without_model_evals():
    rt = AdsalaRuntime()
    sub = StubSub("ref")
    rt.register(sub)
    cfg = ServeConfig(backend="ref", max_batch=1, linger_ms=0.5, workers=1,
                      min_steal=1, brownout_pending=1)
    reqs = [make("gemm", (16, 16, 16), seed=i) for i in range(4)]
    with BlasService(runtime=rt, config=cfg) as svc:
        futs = [svc.submit("gemm", r) for r in reqs]
        outs = [np.asarray(f.result(timeout=120), np.float64) for f in futs]
        assert svc.stats.brownout_batches >= 1
        assert svc.stats.failed == 0
    assert sub.evals == 0 and rt.stats.model_evals == 0
    for r, out in zip(reqs, outs):
        ref = np.asarray(r[0] @ r[1], np.float64)
        assert np.max(np.abs(out - ref)) / (np.max(np.abs(ref)) + 1e-9) \
            < 5e-4
    # control: the same workload without brownout evaluates the model once
    rt2 = AdsalaRuntime()
    sub2 = StubSub("ref")
    rt2.register(sub2)
    with BlasService(runtime=rt2, config=ServeConfig(
            backend="ref", max_batch=1, linger_ms=0.5, workers=1,
            min_steal=1)) as svc2:
        for r in reqs:
            svc2.call("gemm", r)
    assert sub2.evals == 1


# ---------------------------------------------------------------------------
# deadline-bounded backoff: fail with the truth, don't sleep through it
# ---------------------------------------------------------------------------

def test_backoff_bounded_by_request_deadline():
    """With every rung dead and a 3s retry schedule, a 0.3s-deadline
    request must fail DeadlineExpiredError promptly — not sleep through
    the whole backoff and then report ExecutionFailedError."""
    plan = FaultPlan([FaultSpec(site="kernel_execute", times=None)])
    rt = AdsalaRuntime(faults=plan)
    cfg = ServeConfig(backend="cpu_blocked", max_batch=1, linger_ms=0.5,
                      workers=1, min_steal=1, exec_retries=2,
                      retry_backoff_s=1.0, error_budget=False)
    with BlasService(runtime=rt, config=cfg, faults=plan) as svc:
        t0 = time.perf_counter()
        fut = svc.submit("gemm", make("gemm", (16, 16, 16)), deadline=0.3)
        with pytest.raises(DeadlineExpiredError, match="ladder"):
            fut.result(timeout=120)
        elapsed = time.perf_counter() - t0
    # the un-bounded schedule would sleep 1s + 2s per rung; the bound caps
    # the total at roughly the deadline itself
    assert elapsed < 1.5
    assert svc.stats.deadline_expired >= 1
