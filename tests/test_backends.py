"""Tests for the pluggable multi-backend execution layer: registry
round-trips (save → load → identical per-backend decisions), fallback-chain
dispatch, per-backend runtime stats, and runtime thread safety."""

import threading

import numpy as np
import pytest

from repro.backends import (Backend, available_backends, fallback_chain,
                            get_backend, register_backend, resolve_backend,
                            unregister_backend)
from repro.core import (AdsalaRuntime, ModelRegistry, install_backend,
                        install_subroutine)
from repro.kernels import ops, ref


@pytest.fixture(scope="module")
def tuned():
    """Miniature real installs for two backends through install_backend."""
    out = {}
    for name in ("cpu_blocked", "ref"):
        be = get_backend(name)
        out[name] = install_backend(
            be, ops=("gemm",), sizes=(32, 64),
            n_samples=16, dim_lo=32, dim_hi=128,
            max_footprint_bytes=1_000_000, tune_trials=1, seed=0,
            candidates=("LinearRegression", "DecisionTree"))["gemm"]
    return out


# ---------------------------------------------------------------------------
# protocol + registry basics
# ---------------------------------------------------------------------------

def test_builtin_backends_registered():
    assert {"pallas", "cpu_blocked", "ref"} <= set(available_backends())


def test_backends_execute_matches_ref():
    for name in ("pallas", "cpu_blocked"):
        be = get_backend(name)
        for op in ("gemm", "trsm"):
            dims = (48, 32, 40) if op == "gemm" else (48, 40)
            operands = be.make_operands(op, dims, np.float32, seed=3)
            got = np.asarray(be.execute(op, be.prepare(operands),
                                        be.default_knob(op)))
            want = np.asarray(ref.REFS[op](*operands))
            err = np.max(np.abs(got - want)) / (np.max(np.abs(want)) + 1e-9)
            assert err < 5e-4, (name, op, err)


def test_default_knob_is_max_parallelism():
    be = get_backend("cpu_blocked")
    space = be.knob_space("gemm")
    d = be.default_knob("gemm").dict
    assert d["bm"] == min(k.dict["bm"] for k in space)
    assert d["bn"] == min(k.dict["bn"] for k in space)


def test_fallback_chain_resolution():
    assert fallback_chain("nope") == ("nope", "ref")
    assert fallback_chain("ref") == ("ref",)
    assert resolve_backend("nope").name == "ref"
    assert resolve_backend(None).name == "ref"
    assert resolve_backend("cpu_blocked").name == "cpu_blocked"
    # unavailable backends are skipped in favour of ref

    class Dead(Backend):
        name = "dead"

        def is_available(self):
            return False

        def knob_space(self, op, *, sizes=None):
            return get_backend("ref").knob_space(op)

        def execute(self, op, operands, knob=None, **kw):
            raise AssertionError("must never execute")

    register_backend(Dead())
    try:
        assert resolve_backend("dead").name == "ref"
    finally:
        unregister_backend("dead")


def test_run_op_falls_back_to_ref_for_unregistered_backend():
    operands = get_backend("ref").make_operands("gemm", (32, 24, 40),
                                                np.float32, seed=5)
    got = np.asarray(ops.run_op("gemm", operands, backend="not_a_backend"))
    want = np.asarray(ref.gemm(*operands))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_duplicate_registration_rejected():
    with pytest.raises(ValueError):
        register_backend(get_backend("ref"))


# ---------------------------------------------------------------------------
# persistence: backend-tagged round-trip
# ---------------------------------------------------------------------------

def test_registry_roundtrip_identical_decisions_per_backend(tuned, tmp_path):
    reg = ModelRegistry(tmp_path)
    for sub in tuned.values():
        path = reg.save(sub)
        assert path.name.startswith(f"{sub.backend}__")
    assert reg.backends() == ("cpu_blocked", "ref")

    rt = AdsalaRuntime()
    assert reg.load_into(rt) == 2
    assert rt.backends() == ("cpu_blocked", "ref")
    for name, sub in tuned.items():
        for dims in [(48, 48, 48), (96, 64, 128), (128, 128, 128)]:
            assert rt.select("gemm", dims, dtype_bytes=4,
                             backend=name) == sub.select(dims)


def test_registry_backend_filter(tuned, tmp_path):
    reg = ModelRegistry(tmp_path)
    for sub in tuned.values():
        reg.save(sub)
    rt = AdsalaRuntime()
    assert reg.load_into(rt, backend="ref") == 1
    assert rt.backends() == ("ref",)
    assert not rt.has("gemm", 4, backend="cpu_blocked")


def test_legacy_untagged_artifact_loads_as_pallas(tmp_path):
    from repro.core.registry import load_subroutine, pack_state

    space = ops.knob_space_for("gemm", sizes=(32, 64))
    sub = install_subroutine(
        "gemm", space, lambda dims, knob: 1e-3, n_samples=12,
        dim_lo=32, dim_hi=64, max_footprint_bytes=1_000_000,
        tune_trials=1, candidates=("LinearRegression",), use_lof=False)
    state = sub.get_state()
    del state["backend"], state["version"]      # what a v1 writer produced
    p = tmp_path / "gemm_b4.adsala"
    p.write_bytes(pack_state(state))
    loaded = load_subroutine(p)
    assert loaded.backend == "pallas"
    rt = AdsalaRuntime()
    rt.register(loaded)
    assert rt.has("gemm", 4, backend="pallas")


# ---------------------------------------------------------------------------
# runtime: per-backend keying, stats, thread safety
# ---------------------------------------------------------------------------

def test_same_op_different_backends_coexist(tuned):
    rt = AdsalaRuntime()
    for sub in tuned.values():
        rt.register(sub)
    k_cpu = rt.select("gemm", (64, 64, 64), dtype_bytes=4,
                      backend="cpu_blocked")
    k_ref = rt.select("gemm", (64, 64, 64), dtype_bytes=4, backend="ref")
    # the ref backend's space has a single candidate; cpu has many
    assert k_ref == tuned["ref"].knob_space.candidates[0]
    assert k_cpu in tuned["cpu_blocked"].knob_space.candidates


def test_select_or_default_records_stats(tuned):
    rt = AdsalaRuntime()
    rt.register(tuned["cpu_blocked"])
    default = get_backend("cpu_blocked").default_knob("gemm")
    # untuned backend → default path, still counted
    got = rt.select_or_default("gemm", (64, 64, 64), 4, default,
                               backend="pallas")
    assert got == default
    assert rt.stats.calls == 1 and rt.stats.default_calls == 1
    assert rt.stats.backends["pallas"].default_calls == 1
    # tuned backend → model path, hit on the repeat
    rt.select_or_default("gemm", (64, 64, 64), 4, default,
                         backend="cpu_blocked")
    rt.select_or_default("gemm", (64, 64, 64), 4, default,
                         backend="cpu_blocked")
    assert rt.stats.calls == 3 and rt.stats.default_calls == 1
    b = rt.stats.backends["cpu_blocked"]
    assert (b.calls, b.cache_hits, b.default_calls) == (2, 1, 0)
    assert rt.stats.backend_hit_rates["cpu_blocked"] == 0.5
    assert rt.stats.backend_hit_rates["pallas"] == 0.0


def test_concurrent_select_no_cache_corruption(tuned):
    rt = AdsalaRuntime(cache_size=8)
    for sub in tuned.values():
        rt.register(sub)
    dims_pool = [(32 * i, 32 * i, 32 * i) for i in range(1, 7)]
    expected = {(name, dims): sub.select(dims)
                for name, sub in tuned.items() for dims in dims_pool}
    errors = []
    n_threads, n_iters = 8, 60

    def worker(tid):
        try:
            for i in range(n_iters):
                name = ("cpu_blocked", "ref")[(tid + i) % 2]
                dims = dims_pool[(tid * 7 + i) % len(dims_pool)]
                got = rt.select("gemm", dims, dtype_bytes=4, backend=name)
                if got != expected[(name, dims)]:
                    errors.append((name, dims, got))
        except Exception as e:   # noqa: BLE001
            errors.append(repr(e))

    threads = [threading.Thread(target=worker, args=(t,))
               for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors, errors[:5]
    assert rt.cache_len() <= 8
    assert rt.stats.calls == n_threads * n_iters
    assert rt.stats.cache_hits + rt.stats.default_calls <= rt.stats.calls
