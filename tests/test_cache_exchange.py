"""export_cache/import_cache round trips between two LIVE runtimes in one
process — the single-process analogue of the fleet's shared-journal absorb.

The fleet-coherence guarantees all reduce to import_cache's merge rules
when the importer is *non-empty*: same-key collisions (importer's entry
overwritten by the exporter's — journal-last-wins), version-mismatched
entries dropped, quarantines merged with rebased TTLs (and they evict the
importer's now-benched cached knobs), budget records restoring/parking on
the importer's ledger."""

import pytest

from repro.core import AdsalaRuntime
from repro.core.knobs import Knob
from repro.serving.budget import BudgetConfig, ErrorBudgetLedger

BE = "cpu_blocked"
K_A = Knob((("bm", 128), ("bn", 128)))
K_B = Knob((("bm", 64), ("bn", 64)))
K_C = Knob((("bm", 32), ("bn", 32)))


class StubSub:
    """Fixed-knob model with observable eval count and settable version."""

    def __init__(self, knob, backend=BE, op="gemm", dtype_bytes=4,
                 version=0):
        self.backend, self.op, self.dtype_bytes = backend, op, dtype_bytes
        self.knob = knob
        self.artifact_version = version
        self.evals = 0

    def select(self, dims):
        self.evals += 1
        return self.knob


def test_import_into_nonempty_runtime_merges_and_overwrites():
    """Exporter's entries land beside the importer's; a same-key collision
    goes to the exporter (the imported record is newer information, the
    same rule that makes journal replay last-wins)."""
    rt_a = AdsalaRuntime()
    rt_b = AdsalaRuntime()
    rt_a.register(StubSub(K_A))
    sub_b = StubSub(K_B)
    rt_b.register(sub_b)
    # A decided (64,...) and (128,...); B already decided (128,...) —
    # differently, its model picks K_B — plus its own (256,...)
    rt_a.select("gemm", (64, 64, 64), 4, backend=BE)
    rt_a.select("gemm", (128, 64, 64), 4, backend=BE)
    rt_b.select("gemm", (128, 64, 64), 4, backend=BE)
    rt_b.select("gemm", (256, 64, 64), 4, backend=BE)
    assert rt_b.import_cache(rt_a.export_cache()) == 2
    assert rt_b.cache_len() == 3
    # collision key now serves A's knob — as a cache hit, no re-eval
    evals_before = sub_b.evals
    assert rt_b.select("gemm", (128, 64, 64), 4, backend=BE) == K_A
    assert rt_b.select("gemm", (64, 64, 64), 4, backend=BE) == K_A
    assert rt_b.select("gemm", (256, 64, 64), 4, backend=BE) == K_B
    assert sub_b.evals == evals_before
    s = rt_b.stats
    assert s.import_drops_version == 0 and s.import_drops_knob == 0


def test_import_version_mismatch_drops_only_stale_entries():
    """B runs generation 2 of the gemm artifact; A's generation-1
    decisions must not warm B's cache — but A's entries for a subroutine
    B has never registered import as-is (nothing to validate against)."""
    rt_a = AdsalaRuntime()
    rt_b = AdsalaRuntime()
    rt_a.register(StubSub(K_A, version=1))
    rt_a.register(StubSub(K_A, op="syrk", version=1))
    rt_b.register(StubSub(K_B, version=2))          # newer gemm generation
    rt_a.select("gemm", (64, 64, 64), 4, backend=BE)
    rt_a.select("syrk", (64, 64), 4, backend=BE)
    imported = rt_b.import_cache(rt_a.export_cache())
    assert imported == 1                            # the syrk entry only
    assert rt_b.stats.import_drops_version == 1
    # the dropped shape re-evaluates under B's own model
    sub_b_evals = rt_b.select("gemm", (64, 64, 64), 4, backend=BE)
    assert sub_b_evals == K_B
    assert rt_b.stats.model_evals == 1


def test_import_quarantine_merge_rebases_ttl_and_evicts():
    """A's active quarantine crosses into B: B's cached decisions for the
    benched knob are evicted in the same import, B's miss path forces the
    fallback, and the TTL continues from *remaining* time, not full."""
    rt_a = AdsalaRuntime()
    rt_b = AdsalaRuntime()
    rt_b.register(StubSub(K_A))                     # B's model picks K_A
    rt_b.select("gemm", (64, 64, 64), 4, backend=BE)
    assert rt_b.cache_len() == 1
    rt_a.quarantine_knob("gemm", 4, BE, K_A, fallback=K_C, ttl_s=30.0)
    records = rt_a.export_cache()
    assert records[0]["quarantine"] == 1
    assert 0.0 < records[0]["ttl_s"] <= 30.0        # rebased to remaining
    assert rt_b.import_cache(records) == 0          # no decisions rode along
    assert rt_b.is_quarantined("gemm", 4, BE, K_A)
    # the cached K_A decision did not survive the merge...
    assert rt_b.cache_len() == 0
    # ...and re-selection is forced onto the quarantine's fallback
    assert rt_b.select("gemm", (64, 64, 64), 4, backend=BE) == K_C
    assert rt_b.stats.quarantine_forced == 1
    remaining = rt_b.quarantined_knobs()[(BE, "gemm", 4, K_A)]
    assert 0.0 < remaining <= 30.0


def test_import_drops_decision_whose_knob_is_being_quarantined():
    """Quarantine records are reinstated FIRST, so a decision entry in the
    same import whose knob they bench is dropped — order within one
    export payload cannot resurrect a crashing knob."""
    rt_a = AdsalaRuntime()
    rt_b = AdsalaRuntime()
    rt_a.register(StubSub(K_A))
    rt_a.select("gemm", (64, 64, 64), 4, backend=BE)   # caches K_A
    rt_a.quarantine_knob("syrk", 4, BE, K_A, fallback=K_C, ttl_s=30.0)
    # hand-build the hostile ordering: decision before its own quarantine
    records = [r for r in rt_a.export_cache() if not r.get("quarantine")]
    records.append({"quarantine": 1, "backend": BE, "op": "gemm",
                    "dtype_bytes": 4, "knob": K_A.dict,
                    "fallback_knob": K_C.dict, "ttl_s": 30.0})
    assert rt_b.import_cache(records) == 0
    assert rt_b.stats.import_drops_quarantine == 1
    assert rt_b.cache_len() == 0


def test_budget_records_restore_attached_ledger_with_precedence():
    """Budget records ride export_cache: an importer with an ATTACHED
    ledger has its rung state replaced by the exporter's (imported state
    wins over local history), and ``probe_in_s`` rebases onto the
    importer's clock."""
    cfg = BudgetConfig(window=8, threshold=0.5, min_count=2,
                       probe_interval_s=60.0)
    rt_a = AdsalaRuntime()
    led_a = ErrorBudgetLedger(cfg)
    rt_a.attach_budgets(led_a)
    for _ in range(4):
        led_a.record(BE, "gemm", False)
    assert led_a.admit(BE, "gemm") == "skip"        # breaker opens
    rt_b = AdsalaRuntime()
    led_b = ErrorBudgetLedger(cfg)
    rt_b.attach_budgets(led_b)
    for _ in range(4):
        led_b.record(BE, "gemm", True)              # locally healthy...
    assert rt_b.import_cache(rt_a.export_cache()) == 0
    # ...but the imported open breaker takes precedence
    snap = led_b.snapshot()[(BE, "gemm")]
    assert snap["state"] == "open"
    assert snap["failure_rate"] == 1.0
    assert led_b.admit(BE, "gemm") == "skip"        # probe not yet due


def test_budget_records_park_until_ledger_attaches():
    """Importing into a runtime with NO ledger parks the budget records;
    attach_budgets later must deliver them (the fleet executor's startup
    order: warm start first, budgets attached by the service after)."""
    cfg = BudgetConfig(window=8, threshold=0.5, min_count=2,
                       probe_interval_s=60.0)
    rt_a = AdsalaRuntime()
    led_a = ErrorBudgetLedger(cfg)
    rt_a.attach_budgets(led_a)
    for _ in range(4):
        led_a.record(BE, "gemm", False)
    assert led_a.admit(BE, "gemm") == "skip"
    rt_b = AdsalaRuntime()
    assert rt_b.import_cache(rt_a.export_cache()) == 0   # parked
    led_b = ErrorBudgetLedger(cfg)
    rt_b.attach_budgets(led_b)
    assert led_b.snapshot()[(BE, "gemm")]["state"] == "open"
    assert led_b.admit(BE, "gemm") == "skip"


def test_export_order_budget_then_quarantine_then_lru():
    """The export layout the import rules depend on: budget records first,
    quarantines next, decisions LRU-oldest-first last."""
    rt = AdsalaRuntime(touch_sample=1)
    led = ErrorBudgetLedger(BudgetConfig(window=4, threshold=0.5,
                                         min_count=2))
    rt.attach_budgets(led)
    led.record(BE, "gemm", False)
    rt.register(StubSub(K_A))
    rt.select("gemm", (64, 64, 64), 4, backend=BE)
    rt.select("gemm", (128, 64, 64), 4, backend=BE)
    rt.select("gemm", (64, 64, 64), 4, backend=BE)  # refresh (64,...)
    rt.quarantine_knob("syrk", 4, BE, K_B, fallback=K_C, ttl_s=10.0)
    recs = rt.export_cache()
    kinds = [("budget" if r.get("budget") else
              "quarantine" if r.get("quarantine") else "decision")
             for r in recs]
    assert kinds == ["budget", "quarantine", "decision", "decision"]
    decisions = [r for r in recs if not r.get("budget")
                 and not r.get("quarantine")]
    # LRU-oldest first: (128,...) went stale when (64,...) was re-touched
    assert decisions[0]["dims"] == [128, 64, 64]
    assert decisions[1]["dims"] == [64, 64, 64]
