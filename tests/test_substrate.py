"""Substrate tests: optimizer, gradient compression, data pipeline,
checkpointing (atomic/async/restore), fault tolerance, pipeline parallelism
math."""

import os
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.data import ByteCorpusDataset, SyntheticLMDataset
from repro.distributed import (PreemptionGuard, RetryPolicy,
                               StragglerDetector, bubble_fraction)
from repro.optim import (AdamWConfig, adamw_update, compress_decompress,
                         cosine_schedule, global_norm, init_adamw,
                         init_error_feedback, quantize_int8, dequantize_int8)


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_converges_on_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1,
                      total_steps=200, grad_clip=100.0)
    params = {"w": jnp.array([5.0, -3.0])}
    state = init_adamw(params)
    target = jnp.array([1.0, 2.0])
    for _ in range(200):
        g = {"w": 2 * (params["w"] - target)}
        params, state, _ = adamw_update(params, g, state, cfg)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=0.05)


def test_cosine_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_frac=0.1)
    lrs = [float(cosine_schedule(cfg, jnp.asarray(s))) for s in range(101)]
    assert lrs[0] < lrs[9] <= 1.0                  # warmup
    assert abs(lrs[10] - 1.0) < 0.05               # peak
    assert abs(lrs[100] - 0.1) < 0.02              # floor
    assert all(a >= b - 1e-6 for a, b in zip(lrs[10:], lrs[11:]))  # decay


def test_grad_clip_bounds_global_norm():
    cfg = AdamWConfig(grad_clip=1.0)
    g = {"a": jnp.full((10,), 100.0)}
    from repro.optim import clip_by_global_norm
    clipped, gn = clip_by_global_norm(g, 1.0)
    assert float(gn) > 100
    assert float(global_norm(clipped)) <= 1.0 + 1e-5


# ---------------------------------------------------------------------------
# compression
# ---------------------------------------------------------------------------

def test_int8_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.standard_normal(1000), jnp.float32)
    q, s = quantize_int8(g)
    assert q.dtype == jnp.int8
    err = np.abs(np.asarray(dequantize_int8(q, s) - g))
    assert err.max() <= float(s) / 2 + 1e-6


def test_error_feedback_is_unbiased_over_time():
    """With EF, the accumulated applied gradient tracks the true sum."""
    rng = np.random.default_rng(1)
    true = rng.standard_normal(256).astype(np.float32) * 1e-3
    ef = init_error_feedback({"w": jnp.zeros(256)})
    applied = np.zeros(256)
    for _ in range(50):
        g = {"w": jnp.asarray(true)}
        out, ef = compress_decompress(g, ef)
        applied += np.asarray(out["w"])
    np.testing.assert_allclose(applied / 50, true, atol=np.abs(true).max() * 0.05 + 1e-6)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_synthetic_dataset_deterministic_and_resumable():
    ds = SyntheticLMDataset(vocab=100, seq_len=16, global_batch=4, seed=3)
    b1 = ds.batch_at(41)
    b2 = ds.batch_at(41)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(ds.batch_at(42)["tokens"], b1["tokens"])
    assert b1["labels"][0, -1] == -1
    np.testing.assert_array_equal(b1["labels"][:, :-1], b1["tokens"][:, 1:])


def test_byte_corpus_dataset(tmp_path):
    p = tmp_path / "corpus.txt"
    p.write_text("the quick brown fox jumps over the lazy dog. " * 50)
    ds = ByteCorpusDataset(path=p, seq_len=32, global_batch=2, seed=0)
    b = ds.batch_at(0)
    assert b["tokens"].shape == (2, 32)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {"params": {"w": jnp.asarray(rng.standard_normal((8, 4)),
                                        jnp.float32),
                       "b": jnp.asarray(rng.standard_normal(4), jnp.float32)},
            "step": jnp.asarray(7, jnp.int32)}


def test_checkpoint_save_restore_exact(tmp_path):
    ck = Checkpointer(tmp_path)
    t = _tree()
    ck.save(10, t)
    assert ck.latest_step() == 10
    restored = ck.restore(10, jax.tree.map(np.asarray, t))
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_async_and_gc(tmp_path):
    ck = Checkpointer(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        ck.save_async(s, _tree(s))
    ck.wait()
    assert ck.steps() == [3, 4]


def test_checkpoint_atomicity_no_torn_dirs(tmp_path):
    ck = Checkpointer(tmp_path)
    ck.save(5, _tree())
    # a stale tmp dir from a crashed writer must be invisible
    (tmp_path / "step_6.tmp").mkdir()
    assert ck.latest_step() == 5


def test_checkpoint_restore_with_sharding_target(tmp_path):
    """Mesh-agnostic restore: target carries shardings (elastic path)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    ck = Checkpointer(tmp_path)
    t = _tree()
    ck.save(1, t)
    mesh = jax.make_mesh((1,), ("data",))
    target = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(
            x.shape, x.dtype,
            sharding=NamedSharding(mesh, P(*([None] * x.ndim)))), t)
    restored = ck.restore(1, target)
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(t["params"]["w"]))


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------

def test_straggler_detector_flags_slow_step():
    det = StragglerDetector(threshold=3.0, min_steps=3)
    for _ in range(10):
        assert not det.observe(1.0)
    assert det.observe(10.0)
    assert det.stragglers == 1
    # EWMA not poisoned by the straggler
    assert det.expected_step_seconds < 1.5


def test_retry_policy_retries_then_succeeds():
    calls = []

    def fn(attempt):
        calls.append(attempt)
        if attempt < 2:
            raise RuntimeError("node died")
        return "ok"

    out = RetryPolicy(max_retries=3).run(fn, sleep=lambda s: None)
    assert out == "ok" and calls == [0, 1, 2]


def test_retry_policy_exhausts():
    def fn(attempt):
        raise RuntimeError("permafail")

    with pytest.raises(RuntimeError):
        RetryPolicy(max_retries=1).run(fn, sleep=lambda s: None)


def test_preemption_guard_flag():
    g = PreemptionGuard(install_handlers=False)
    assert not g.preempted
    g.simulate()
    assert g.preempted


def test_bubble_fraction():
    assert bubble_fraction(4, 12) == pytest.approx(3 / 15)
    assert bubble_fraction(1, 8) == 0.0


# ---------------------------------------------------------------------------
# end-to-end: train → preempt → resume (single device)
# ---------------------------------------------------------------------------

def test_train_resume_after_preemption(tmp_path):
    from repro.configs import get_smoke_config
    from repro.data import SyntheticLMDataset
    from repro.launch.train import TrainLoop
    from repro.distributed import best_mesh

    cfg = get_smoke_config("llama3-8b")
    ds = SyntheticLMDataset(vocab=cfg.vocab, seq_len=32, global_batch=2)
    loop = TrainLoop(cfg=cfg, adamw=AdamWConfig(total_steps=20),
                     mesh=best_mesh(), ckpt=Checkpointer(tmp_path),
                     dataset=ds, ckpt_every=5, log_every=100)
    guard = PreemptionGuard(install_handlers=False)
    # preempt after ~6 steps via a watcher thread flag
    state0 = loop.init_state()
    res = loop.run(6, guard=guard, start_step=0, state=state0)
    assert res["final_step"] == 6
    step2, _ = loop.restore_or_init()
    assert step2 >= 5           # resumed from a checkpoint
    res2 = loop.run(10, guard=guard)
    assert res2["final_step"] == 10
