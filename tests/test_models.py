"""Architecture-zoo tests: per-arch smoke (forward/train step, shapes, no
NaNs), serving equivalence (prefill+decode == full forward), and layer-level
correctness (flash attention vs naive, SSD vs per-token recurrence, WKV vs
per-token recurrence)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHITECTURES, get_config, get_smoke_config, SHAPES, \
    shape_applicable
from repro.models import (decode_step, forward, init_decode_state,
                          init_params, loss_fn, prefill)
from repro.models.layers import Ctx, flash_attention
from repro.models.transformer import _run_encoder


def _batch_for(cfg, B, S, seed=0):
    rng = np.random.default_rng(seed)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)),
                                   jnp.int32)}
    batch["labels"] = batch["tokens"]
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((B, cfg.enc_seq, cfg.d_model)), jnp.float32)
    if cfg.family == "vlm":
        batch["vision"] = jnp.asarray(
            rng.standard_normal((B, cfg.vision_tokens, cfg.d_model)),
            jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHITECTURES)
def test_smoke_forward_and_grad(arch):
    cfg = get_smoke_config(arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    B, S = 2, 64
    batch = _batch_for(cfg, B, S)
    logits, _ = forward(params, batch, cfg)
    exp_seq = S + (cfg.vision_tokens if cfg.family == "vlm" else 0)
    assert logits.shape == (B, exp_seq, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    loss, _ = loss_fn(params, batch, cfg)
    g = jax.grad(lambda p: loss_fn(p, batch, cfg)[0])(params)
    assert np.isfinite(float(loss))
    assert all(np.isfinite(np.asarray(x, np.float32)).all()
               for x in jax.tree.leaves(g))


@pytest.mark.parametrize("arch", ARCHITECTURES)
def test_one_train_step_reduces_nothing_nan(arch):
    from repro.optim import AdamWConfig, adamw_update, init_adamw
    cfg = get_smoke_config(arch)
    params = init_params(jax.random.PRNGKey(1), cfg)
    opt = init_adamw(params)
    batch = _batch_for(cfg, 2, 32)
    (_, _), grads = jax.value_and_grad(
        lambda p: loss_fn(p, batch, cfg), has_aux=True)(params)
    new_p, new_o, m = adamw_update(params, grads, opt,
                                   AdamWConfig(total_steps=10))
    assert int(new_o.step) == 1
    assert np.isfinite(float(m["grad_norm"]))
    # params actually moved
    moved = any(not np.allclose(np.asarray(a, np.float32),
                                np.asarray(b, np.float32))
                for a, b in zip(jax.tree.leaves(params),
                                jax.tree.leaves(new_p)))
    assert moved


@pytest.mark.parametrize("arch", ARCHITECTURES)
def test_prefill_decode_matches_forward(arch):
    cfg = dataclasses.replace(get_smoke_config(arch),
                              compute_dtype="float32", capacity_factor=8.0)
    params = init_params(jax.random.PRNGKey(2), cfg)
    B, S = 2, 33
    batch = _batch_for(cfg, B, S, seed=2)
    full, _ = forward(params, batch, cfg)
    caches = init_decode_state(cfg, B, max_len=64, dtype=jnp.float32)
    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :-1]
    logits_pre, caches = prefill(params, pre, caches, cfg)
    enc_out = (_run_encoder(params, batch["frames"], Ctx(cfg))
               if cfg.family == "audio" else None)
    logits_dec, _ = decode_step(params, batch["tokens"][:, -1:], caches, cfg,
                                enc_out=enc_out, pos=S - 1)
    np.testing.assert_allclose(np.asarray(logits_pre[:, 0]),
                               np.asarray(full[:, -2]), atol=2e-3)
    np.testing.assert_allclose(np.asarray(logits_dec[:, 0]),
                               np.asarray(full[:, -1]), atol=2e-3)


def test_flash_attention_matches_naive_gqa():
    rng = np.random.default_rng(0)
    B, S, H, KH, D = 2, 48, 6, 3, 8
    q = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, KH, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, KH, D)), jnp.float32)
    G = H // KH
    q_ = q.reshape(B, S, KH, G, D)
    s = jnp.einsum("bqhgd,bkhd->bqghk", q_, k) / np.sqrt(D)
    mask = np.tril(np.ones((S, S), bool))
    s = jnp.where(mask[None, :, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, -1)
    want = jnp.einsum("bqghk,bkhd->bqghd", p, v).transpose(0, 1, 3, 2, 4
                                                           ).reshape(B, S, H, D)
    for qc, kc, skip in [(16, 16, False), (8, 24, False), (16, 16, True)]:
        got = flash_attention(q, k, v, causal=True, q_chunk=qc, k_chunk=kc,
                              causal_skip=skip)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-4)


def test_ssd_chunked_equals_per_token_recurrence():
    from repro.models.mamba2 import _ssd_chunked
    from repro.configs.base import ModelConfig
    rng = np.random.default_rng(1)
    B, S, H, P, N = 2, 37, 4, 8, 8
    cfg = ModelConfig(name="t", family="dense", n_layers=1, d_model=8,
                      n_heads=1, kv_heads=1, d_ff=8, vocab=8, ssm_chunk=8,
                      ssm_state=N, ssm_headdim=P, ssm_groups=1)
    x = jnp.asarray(rng.standard_normal((B, S, H, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.5, (B, S, H)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.1, 1.0, (H,)), jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((B, S, 1, N)), jnp.float32)
    Cm = jnp.asarray(rng.standard_normal((B, S, 1, N)), jnp.float32)
    h0 = jnp.zeros((B, H, P, N))
    y, hT = _ssd_chunked(x, dt, A, Bm, Cm, cfg, h0)
    # reference per-token recurrence
    h = np.zeros((B, H, P, N))
    ys = []
    xn, dtn, Bn, Cn = map(np.asarray, (x, dt, Bm, Cm))
    An = np.asarray(A)
    for t in range(S):
        dA = np.exp(dtn[:, t] * An)                       # (B,H)
        Bt = np.repeat(Bn[:, t], H, axis=1)               # (B,H,N)
        Ct = np.repeat(Cn[:, t], H, axis=1)
        h = h * dA[:, :, None, None] + np.einsum(
            "bh,bhn,bhp->bhpn", dtn[:, t], Bt, xn[:, t])
        ys.append(np.einsum("bhn,bhpn->bhp", Ct, h))
    want = np.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y), want, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(hT), h, rtol=1e-4, atol=1e-4)


def test_wkv_chunked_equals_per_token_recurrence():
    from repro.models.rwkv6 import _wkv_chunked
    rng = np.random.default_rng(2)
    B, S, H, K = 2, 29, 2, 4
    r = jnp.asarray(rng.standard_normal((B, S, H, K)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, H, K)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, H, K)), jnp.float32)
    w_log = jnp.asarray(-rng.uniform(0.01, 2.0, (B, S, H, K)), jnp.float32)
    u = jnp.asarray(rng.standard_normal((H, K)), jnp.float32)
    S0 = jnp.zeros((B, H, K, K))
    y, Sf = _wkv_chunked(r, k, v, w_log, u, 8, S0)
    # reference
    Sref = np.zeros((B, H, K, K))
    rn, kn, vn, wn, un = map(np.asarray, (r, k, v, w_log, u))
    ys = []
    for t in range(S):
        yt = np.einsum("bhk,bhkv->bhv", rn[:, t], Sref) + \
            np.einsum("bhk,bhk,bhv->bhv", rn[:, t], un[None] * kn[:, t],
                      vn[:, t])
        Sref = Sref * np.exp(wn[:, t])[..., None] + \
            np.einsum("bhk,bhv->bhkv", kn[:, t], vn[:, t])
        ys.append(yt)
    want = np.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y), want, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(Sf), Sref, rtol=2e-3, atol=2e-3)


def test_moe_router_mass_conservation_no_drop():
    """With generous capacity, combine weights sum to 1 per token."""
    from repro.models.moe import moe_ffn, init_moe
    from repro.models.layers import Ctx
    cfg = dataclasses.replace(get_smoke_config("granite_moe_3b"),
                              capacity_factor=8.0, compute_dtype="float32")
    p = init_moe(jax.random.PRNGKey(0), cfg)
    # identity experts: wd = pinv-ish — instead check linearity: zero input
    x = jnp.zeros((2, 16, cfg.d_model), jnp.float32)
    out, aux = moe_ffn(p, x, Ctx(cfg))
    np.testing.assert_allclose(np.asarray(out), 0.0, atol=1e-6)
    assert np.isfinite(float(aux))


def test_long_context_skip_rules():
    cfg_attn = get_config("llama3-8b")
    cfg_ssm = get_config("rwkv6-1.6b")
    ok, reason = shape_applicable(cfg_attn, SHAPES["long_500k"])
    assert not ok and "sub-quadratic" in reason
    ok, _ = shape_applicable(cfg_ssm, SHAPES["long_500k"])
    assert ok
