"""From-scratch ML library: fit quality, serialization, tuning, selection."""

import numpy as np
import pytest

from repro.core.ml import (PAPER_CANDIDATES, cross_val_rmse, make_model,
                           rmse, tune_model)
from repro.core import (AdsalaRuntime, ModelRegistry, block_knob_space,
                        install_subroutine, oracle_time)


def _toy(n=300, d=5, seed=0, nonlinear=True):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, d))
    y = 2 * X[:, 0] - X[:, 1]
    if nonlinear:
        y = y + X[:, 2] ** 2 + np.where(X[:, 3] > 0, 3.0, -1.0)
    return X, y + 0.05 * rng.normal(size=n)


@pytest.mark.parametrize("name", PAPER_CANDIDATES)
def test_fit_beats_mean_predictor(name):
    X, y = _toy()
    Xt, yt = _toy(seed=1)
    m = make_model(name).fit(X, y)
    assert rmse(yt, m.predict(Xt)) < rmse(yt, np.full_like(yt, y.mean()))


@pytest.mark.parametrize("name", PAPER_CANDIDATES)
def test_state_roundtrip_exact(name):
    X, y = _toy(n=150)
    m = make_model(name).fit(X, y)
    m2 = make_model(name)
    m2.set_state(m.get_state())
    np.testing.assert_allclose(m.predict(X), m2.predict(X), rtol=1e-12)


def test_nonlinear_models_beat_linear_on_nonlinear_target():
    X, y = _toy(n=500)
    Xt, yt = _toy(n=300, seed=2)
    lin = make_model("LinearRegression").fit(X, y)
    xgb = make_model("XGBoost").fit(X, y)
    assert rmse(yt, xgb.predict(Xt)) < 0.8 * rmse(yt, lin.predict(Xt))


def test_tune_model_returns_fitted_and_not_worse():
    X, y = _toy(n=250)
    base = make_model("DecisionTree", max_depth=2)
    tuned = tune_model(base, X, y, n_trials=4, cv=3, seed=0)
    assert tuned.predict(X).shape == y.shape
    assert cross_val_rmse(tuned.clone(), X, y) <= \
        cross_val_rmse(base.clone(), X, y) * 1.1


def test_linear_regression_exact_on_linear_data():
    rng = np.random.default_rng(0)
    X = rng.normal(size=(100, 3))
    w = np.array([1.0, -2.0, 0.5])
    y = X @ w + 3.0
    m = make_model("LinearRegression").fit(X, y)
    np.testing.assert_allclose(m.predict(X), y, atol=1e-8)


def test_bayesian_ridge_shrinks_with_noise():
    rng = np.random.default_rng(0)
    X = rng.normal(size=(60, 8))
    y = X[:, 0] + 5.0 * rng.normal(size=60)    # mostly noise
    br = make_model("BayesianRidge").fit(X, y)
    ols = make_model("LinearRegression").fit(X, y)
    assert np.linalg.norm(br.coef_[:-1]) < np.linalg.norm(ols.coef_[:-1])


# ---------------------------------------------------------------------------
# end-to-end install → runtime → registry (oracle-timed, fast)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def installed(tmp_path_factory):
    rng = np.random.default_rng(0)
    space = block_knob_space(bms=(128, 256), bks=(128, 256), bns=(128, 256))
    sub = install_subroutine(
        "gemm", space,
        lambda dims, knob: oracle_time("gemm", dims, knob, dtype_bytes=2,
                                       noise_rng=rng),
        n_samples=40, dim_lo=64, dim_hi=2048, max_footprint_bytes=None,
        dtype_bytes=2, candidates=("LinearRegression", "DecisionTree"),
        tune_trials=2)
    return sub, tmp_path_factory.mktemp("reg")


def test_install_selects_by_estimated_speedup(installed):
    sub, _ = installed
    best = max(sub.reports, key=lambda r: r.estimated_mean_speedup)
    assert sub.model_name == best.name
    for r in sub.reports:
        assert r.eval_time_us > 0
        assert np.isfinite(r.estimated_mean_speedup)


def test_runtime_memoization_and_argmin(installed):
    sub, _ = installed
    rt = AdsalaRuntime()
    rt.register(sub)
    k1 = rt.select("gemm", (512, 512, 512), dtype_bytes=2)
    k2 = rt.select("gemm", (512, 512, 512), dtype_bytes=2)
    assert k1 == k2 and rt.stats.cache_hits == 1
    # the selection is the argmin of the model's own predictions
    pred = sub.predict_times((512, 512, 512))
    assert sub.knob_space.candidates[int(np.argmin(pred))] == k1


def test_registry_roundtrip_same_decisions(installed):
    sub, reg_dir = installed
    reg = ModelRegistry(reg_dir)
    reg.save(sub)
    rt = AdsalaRuntime()
    assert reg.load_into(rt) == 1
    for dims in [(128, 256, 512), (1024, 64, 2048), (300, 300, 300)]:
        assert rt.select("gemm", dims, dtype_bytes=2) == sub.select(dims)


def test_runtime_graceful_default_for_untuned_op(installed):
    sub, _ = installed
    rt = AdsalaRuntime()
    rt.register(sub)
    from repro.kernels.ops import default_knob
    got = rt.select_or_default("trsm", (256, 256), 4, default_knob("trsm"))
    assert got == default_knob("trsm")
