"""Per-kernel allclose sweeps: Pallas (interpret=True) and the numpy blocked
black-box BLAS vs the pure-jnp oracles, across shapes, dtypes, blocks and
variants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core.knobs import Knob
from repro.kernels import ops, ref
from repro.kernels.cpu_blocked import make_operands, run_blocked

OPS = ("gemm", "symm", "syrk", "syr2k", "trmm", "trsm")


def _knob(bm, bk, bn, variant="full"):
    return Knob(tuple(sorted({"bm": bm, "bk": bk, "bn": bn,
                              "variant": variant}.items())))


def _dims_for(op, m, k, n):
    return {"gemm": (m, k, n), "symm": (m, n), "syrk": (n, k),
            "syr2k": (n, k), "trmm": (m, n), "trsm": (m, n)}[op]


def _rel_err(got, want):
    got = np.asarray(got, np.float64)
    want = np.asarray(want, np.float64)
    return np.max(np.abs(got - want)) / (np.max(np.abs(want)) + 1e-9)


@pytest.mark.parametrize("op", OPS)
@pytest.mark.parametrize("dims_idx,dims3", [
    (0, (128, 128, 128)),
    (1, (256, 128, 384)),
    (2, (100, 50, 130)),        # padding path
])
def test_pallas_matches_ref_f32(op, dims_idx, dims3):
    dims = _dims_for(op, *dims3)
    operands = tuple(jnp.asarray(x)
                     for x in make_operands(op, dims, np.float32, seed=dims_idx))
    out = ops.run_op(op, operands, knob=_knob(128, 128, 128),
                     interpret=True)
    want = ref.REFS[op](*operands)
    assert out.shape == want.shape
    assert _rel_err(out, want) < 2e-4, op


@pytest.mark.parametrize("op", ("syrk", "syr2k", "trmm"))
def test_tri_variant_matches_full(op):
    dims = _dims_for(op, 256, 128, 256)
    operands = tuple(jnp.asarray(x)
                     for x in make_operands(op, dims, np.float32, seed=7))
    full = ops.run_op(op, operands, knob=_knob(128, 128, 128, "full"),
                      interpret=True)
    tri = ops.run_op(op, operands, knob=_knob(128, 128, 128, "tri"),
                     interpret=True)
    assert _rel_err(tri, full) < 1e-5


@pytest.mark.parametrize("op", OPS)
def test_pallas_bf16(op):
    dims = _dims_for(op, 128, 128, 128)
    operands = tuple(jnp.asarray(x, jnp.bfloat16)
                     for x in make_operands(op, dims, np.float32, seed=3))
    out = ops.run_op(op, operands, knob=_knob(128, 128, 128), interpret=True)
    want = ref.REFS[op](*(o.astype(jnp.float32) for o in operands))
    tol = 0.1 if op == "trsm" else 0.05   # bf16 solve accumulates error
    assert _rel_err(out.astype(jnp.float32), want) < tol, op


@pytest.mark.parametrize("op", OPS)
@pytest.mark.parametrize("blocks", [(128, 128, 256), (256, 256, 128)])
def test_block_config_invariance(op, blocks):
    """The knob changes runtime, never semantics (the ADSALA contract)."""
    dims = _dims_for(op, 256, 256, 256)
    operands = tuple(jnp.asarray(x)
                     for x in make_operands(op, dims, np.float32, seed=11))
    a = ops.run_op(op, operands, knob=_knob(*blocks), interpret=True)
    b = ops.run_op(op, operands, knob=_knob(128, 128, 128), interpret=True)
    assert _rel_err(a, b) < 1e-5


@given(op=st.sampled_from(OPS),
       m=st.integers(8, 96), k=st.integers(8, 96), n=st.integers(8, 96),
       bm=st.sampled_from([16, 32, 64]), bn=st.sampled_from([16, 32, 64]),
       variant=st.sampled_from(["full", "tri"]))
@settings(max_examples=40, deadline=None)
def test_numpy_blocked_property_sweep(op, m, k, n, bm, bn, variant):
    """The calibration executor equals the oracle for arbitrary shapes/blocks
    (hypothesis sweep; f64 so the only error is algorithmic)."""
    dims = _dims_for(op, m, k, n)
    operands = make_operands(op, dims, np.float64, seed=m * 131 + n)
    got = run_blocked(op, operands, _knob(bm, bm, bn, variant))
    # jnp ref runs in f32 (x64 off) → f32-level agreement is the bound here
    want = np.asarray(ref.REFS[op](*(jnp.asarray(o) for o in operands)))
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=1e-5)


def test_alpha_beta_semantics():
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((128, 128)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((128, 128)), jnp.float32)
    c = jnp.asarray(rng.standard_normal((128, 128)), jnp.float32)
    out = ops.gemm(a, b, c, alpha=2.0, beta=0.5, knob=_knob(128, 128, 128),
                   interpret=True)
    want = 2.0 * (a @ b) + 0.5 * c
    assert _rel_err(out, want) < 1e-5


def test_trsm_solves_system():
    rng = np.random.default_rng(1)
    m, n = 256, 64
    a = jnp.asarray(rng.standard_normal((m, m)) + m * np.eye(m), jnp.float32)
    x_true = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
    b = jnp.tril(a) @ x_true
    x = ops.trsm(a, b, knob=_knob(128, 128, 128), interpret=True)
    assert _rel_err(x, x_true) < 1e-4
