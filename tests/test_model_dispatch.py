"""ADSALA-dispatched model inference (PR 6).

Contracts:

  * routing every dense matmul of the transformer through
    ``run_op``/:class:`AdsalaRuntime` is **bitwise** identical to the plain
    ``x @ w`` path — for the dense, MoE and MLA families, on forward,
    prefill and decode_step — whenever every contraction dim fits one
    k-tile (≤ 128: the f32 accumulation is then a single exact jnp.dot);
  * ``run_op``/the kernels take leading-batch activations *natively*
    (3-D a against a shared 2-D weight — no reshape-collapse, no per-item
    loop over copies);
  * the ahead-of-time harvest (``roofline.harvest``) sees every decision
    key the routed programs will request — including the skinny
    ``(1, d, n)`` decode GEMMs — with zero model evaluations;
  * install → ``select_many`` → ``save_decision_cache`` offline, then a
    fresh runtime hydrated from the registry serves prefill + decode with
    **zero** runtime model evaluations.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.backends import resolve_backend
from repro.configs import get_smoke_config
from repro.core.oracle import oracle_time
from repro.core.registry import ModelRegistry
from repro.core.runtime import AdsalaRuntime
from repro.core.tuner import install_subroutine
from repro.kernels import ops
from repro.kernels.gemm import gemm_pallas
from repro.models import transformer as tf
from repro.models.layers import Ctx, routed_matmul
from repro.roofline.costing import prune_dominated_candidates
from repro.roofline.harvest import (Recorder, dot_call_sites,
                                    harvest_decision_keys)

#: dense / MoE / MLA — one routed arch per family
ARCHS = ("qwen15_4b", "granite_moe_3b", "deepseek_v2_lite")


def _cfg(arch):
    """Parity config: every contraction dim (d_model, d_ff, moe_d_ff,
    kv_lora, heads·v_head_dim) ≤ 128 → single k-tile → bitwise."""
    return dataclasses.replace(get_smoke_config(arch),
                               compute_dtype="float32",
                               capacity_factor=8.0, d_ff=128)


def _batch(cfg, B, S, seed=0):
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(seed),
                                          (B, S), 0, cfg.vocab)}
    if cfg.vision_tokens:
        batch["vision"] = jax.random.normal(
            jax.random.PRNGKey(seed + 1), (B, cfg.vision_tokens, 32))
    return batch


# ---------------------------------------------------------------------------
# bit parity: routed == unrouted on forward / prefill / decode_step
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ARCHS)
def test_routed_forward_bit_identical(arch):
    cfg = _cfg(arch)
    rcfg = dataclasses.replace(cfg, use_pallas_gemm=True)
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg, 2, 16)
    ref, _ = tf.forward(params, batch, cfg)
    rt = AdsalaRuntime()
    out, _ = tf.forward(params, batch, rcfg, runtime=rt)
    assert jnp.array_equal(ref, out), \
        f"maxdiff {float(jnp.max(jnp.abs(ref - out)))}"
    # untuned runtime: every decision fell through to the default knob
    assert rt.stats.for_backend("pallas").model_evals == 0
    assert rt.stats.for_backend("pallas").default_calls > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_routed_prefill_decode_bit_identical(arch):
    cfg = _cfg(arch)
    rcfg = dataclasses.replace(cfg, use_pallas_gemm=True)
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    B, S = 2, 16
    batch = _batch(cfg, B, S)
    lu, cu = tf.prefill(params, batch, tf.init_decode_state(cfg, B, S + 4),
                        cfg)
    rt = AdsalaRuntime()
    lr, cr = tf.prefill(params, batch, tf.init_decode_state(rcfg, B, S + 4),
                        rcfg, runtime=rt)
    assert jnp.array_equal(lu, lr)
    tok = jnp.argmax(lu[:, -1:], -1).astype(jnp.int32)
    du, _ = tf.decode_step(params, tok, cu, cfg)
    dr, _ = tf.decode_step(params, tok, cr, rcfg, runtime=rt)
    assert jnp.array_equal(du, dr)


def test_routing_respects_config_gates():
    cfg = _cfg("qwen15_4b")
    from repro.models.sharding import DEFAULT_RULES
    x = jnp.ones((2, 8, cfg.d_model))
    w = jnp.ones((cfg.d_model, 32))
    # unrouted config → plain matmul (trivially, no pallas trace)
    ctx = Ctx(cfg, None, DEFAULT_RULES)
    assert not ctx.routes_gemm(x)
    assert jnp.array_equal(routed_matmul(x, w, ctx), x @ w)
    # routed config but a live mesh → sharded einsum path stays untouched
    rcfg = dataclasses.replace(cfg, use_pallas_gemm=True)
    assert not Ctx(rcfg, object(), DEFAULT_RULES).routes_gemm(x)
    # routed, meshless → dispatches (and still matches bitwise)
    rctx = Ctx(rcfg, None, DEFAULT_RULES)
    assert rctx.routes_gemm(x)
    assert jnp.array_equal(routed_matmul(x, w, rctx), x @ w)


def test_routed_matmul_high_rank_leading_batch():
    """≥2 leading axes fold into one stack axis outside any jit loop."""
    rcfg = dataclasses.replace(_cfg("qwen15_4b"), use_pallas_gemm=True)
    from repro.models.sharding import DEFAULT_RULES
    ctx = Ctx(rcfg, None, DEFAULT_RULES)
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 3, 8, 64))
    w = jax.random.normal(jax.random.PRNGKey(1), (64, 32))
    got = routed_matmul(x, w, ctx)
    assert got.shape == (2, 3, 8, 32)
    assert jnp.array_equal(got, x @ w)


# ---------------------------------------------------------------------------
# native leading-batch gemm (shared 2-D weight, no collapse/copy)
# ---------------------------------------------------------------------------

def test_gemm_pallas_shared_weight_batched():
    a = jax.random.normal(jax.random.PRNGKey(0), (3, 33, 96))
    b = jax.random.normal(jax.random.PRNGKey(1), (96, 160))
    got = gemm_pallas(a, b, bm=128, bk=128, bn=128, interpret=True)
    want = a @ b
    assert got.shape == want.shape
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_run_op_stacked_shared_weight():
    a = jax.random.normal(jax.random.PRNGKey(0), (4, 17, 64))
    b = jax.random.normal(jax.random.PRNGKey(1), (64, 48))
    got = ops.run_op("gemm", (a, b), interpret=True)
    assert jnp.array_equal(got, a @ b)   # k=64 ≤ 128 → bitwise


def test_run_op_stacked_both_batched():
    a = jax.random.normal(jax.random.PRNGKey(0), (3, 16, 64))
    b = jax.random.normal(jax.random.PRNGKey(1), (3, 64, 32))
    got = ops.run_op("gemm", (a, b), interpret=True)
    assert jnp.array_equal(got, jnp.einsum("bmk,bkn->bmn", a, b))


@pytest.mark.parametrize("backend", ("ref", "cpu_blocked"))
def test_execute_stacked_shared_weight_other_backends(backend):
    be = resolve_backend(backend)
    rng = np.random.default_rng(0)
    a = rng.standard_normal((3, 20, 24)).astype(np.float32)
    b = rng.standard_normal((24, 16)).astype(np.float32)
    got = np.asarray(be.execute_stacked("gemm", (a, b)))
    want = a.astype(np.float64) @ b.astype(np.float64)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_dims_of_ignores_leading_batch():
    assert ops.dims_of("gemm", ((5, 33, 64), (64, 96))) == (33, 64, 96)
    assert ops.dims_of("gemm", ((33, 64), (64, 96))) == (33, 64, 96)


# ---------------------------------------------------------------------------
# ahead-of-time harvest
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ARCHS)
def test_harvest_covers_decode_gemms(arch):
    cfg = _cfg(arch)
    keys = harvest_decision_keys(cfg, batch_size=2, seq_len=16)
    assert keys, "routed model harvested no decision keys"
    assert all(k[0] == "pallas" and k[1] == "gemm" for k in keys)
    # the skinny decode-step GEMMs (m = one token) must be present —
    # missing them means the first decode pays a cold model eval
    assert any(k[3][0] == 1 for k in keys)
    # deterministic: same trace → same keys, no duplicates
    assert keys == harvest_decision_keys(cfg, batch_size=2, seq_len=16)
    assert len(set(keys)) == len(keys)


def test_recorder_is_pure_bookkeeping():
    rec = Recorder()
    from repro.kernels.ops import default_knob
    d = default_knob("gemm")
    assert rec.select_or_default("gemm", (8, 8, 8), 4, d) is d
    assert rec.keys == [("pallas", "gemm", 4, (8, 8, 8))]
    assert rec.stats.model_evals == 0


def test_harvest_unrouted_config_is_empty_vs_routed():
    cfg = _cfg("qwen15_4b")
    # harvest forces the routed path regardless of the input config's flag
    routed = harvest_decision_keys(
        dataclasses.replace(cfg, use_pallas_gemm=True), seq_len=16)
    assert harvest_decision_keys(cfg, seq_len=16) == routed


def test_dot_call_sites_sees_unrouted_matmuls():
    cfg = _cfg("qwen15_4b")
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg, 2, 16)
    sites = dot_call_sites(lambda p, b: tf.forward(p, b, cfg), params, batch)
    assert sites and all(s[0] == "gemm" and len(s[1]) == 3 for s in sites)


def test_prune_dominated_candidates():
    be = resolve_backend("pallas")
    space = be.knob_space("gemm", sizes=(128, 256, 512))
    dims = [(4096, 2048, 2048), (1, 2048, 2048)]
    pruned = prune_dominated_candidates("gemm", space, dims, dtype_bytes=2,
                                        slack=0.15)
    assert 0 < len(pruned) < len(space)
    # each site's oracle argmin must survive the prune
    for d in dims:
        best = min(space.candidates,
                   key=lambda c: oracle_time("gemm", d, c, dtype_bytes=2))
        assert best in pruned.candidates
    # parallelism definition (the nt-analogue feature) is preserved
    k = pruned.candidates[0]
    assert pruned.parallelism(k, dims[0]) == space.parallelism(k, dims[0])
    # empty dims list = nothing to prove = untouched space
    assert prune_dominated_candidates("gemm", space, []) is space


# ---------------------------------------------------------------------------
# offline prewarm → zero runtime model evaluations
# ---------------------------------------------------------------------------

def test_prewarm_serves_with_zero_model_evals(tmp_path):
    B, S = 2, 16
    rcfg = dataclasses.replace(_cfg("qwen15_4b"), use_pallas_gemm=True)
    backend = resolve_backend("pallas")
    keys = harvest_decision_keys(rcfg, batch_size=B, seq_len=S,
                                 programs=("prefill", "decode"))
    db = keys[0][2]
    space = prune_dominated_candidates(
        "gemm", backend.knob_space("gemm", sizes=(128, 256)),
        [k[3] for k in keys], dtype_bytes=db)
    registry = ModelRegistry(tmp_path)
    install_rt = AdsalaRuntime()
    sub = install_subroutine(
        "gemm", space,
        lambda dims, knob: oracle_time("gemm", dims, knob, dtype_bytes=db),
        n_samples=30, dim_lo=16, dim_hi=256, dtype_bytes=db,
        backend="pallas", tune_trials=2)
    registry.save(sub)
    install_rt.register(sub)
    install_rt.select_many([(op, dims, b, be) for (be, op, b, dims) in keys],
                          record_hits=False)
    registry.save_decision_cache(install_rt)

    params = tf.init_params(jax.random.PRNGKey(0), rcfg)
    batch = _batch(rcfg, B, S)

    def serve(runtime) -> int:
        caches = tf.init_decode_state(rcfg, B, S + 4)
        logits, caches = tf.prefill(params, batch, caches, rcfg,
                                    runtime=runtime)
        tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
        tf.decode_step(params, tok, caches, rcfg, runtime=runtime)
        return int(runtime.stats.for_backend("pallas").model_evals)

    # without the persisted cache every distinct shape pays a model eval
    cold = AdsalaRuntime()
    registry.load_into(cold, backend="pallas")
    assert serve(cold) > 0
    # with it: all trace-time decisions are cache hits — zero evals
    warm = AdsalaRuntime()
    registry.load_into(warm, backend="pallas")
    assert registry.load_decision_cache(warm) == len(keys)
    assert serve(warm) == 0
