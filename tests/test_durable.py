"""Durable snapshot+journal layer (``repro.core.durable``) and the
corruption-tolerant registry recovery built on it: checksummed record
round-trips, torn-tail journal semantics, injected torn writes, and
``load_decision_cache`` degrading to a counted cold start on every flavour
of damaged payload instead of propagating."""

import json

import pytest

from repro.core import AdsalaRuntime, ModelRegistry
from repro.core.durable import (MAGIC, DurableStore, TornWrite,
                                append_journal, decode_line, encode_record,
                                is_durable, read_records, write_snapshot)
from repro.core.knobs import Knob
from repro.serving.faults import FaultPlan, FaultSpec


class StubSub:
    def __init__(self, backend: str = "b0", op: str = "gemm",
                 dtype_bytes: int = 4) -> None:
        self.backend, self.op, self.dtype_bytes = backend, op, dtype_bytes
        self.knob = Knob((("bm", 128), ("bn", 128)))
        self.artifact_version = 0
        self.evals = 0

    def select(self, dims):
        self.evals += 1
        return self.knob


# ---------------------------------------------------------------------------
# record encoding: every damaged line decodes to None, never raises
# ---------------------------------------------------------------------------

def test_record_round_trip():
    rec = {"op": "gemm", "dims": [32, 32, 32], "knob": {"bm": 64}}
    assert decode_line(encode_record(rec)) == rec


def test_decode_line_rejects_damage():
    line = encode_record({"a": 1})
    assert decode_line("") is None
    assert decode_line(line[:-2]) is None              # truncated payload
    assert decode_line("00000000 " + line.split(" ", 1)[1]) is None
    assert decode_line("nospacehere") is None
    # a checksum-valid non-dict payload is still rejected
    import zlib
    payload = "[1,2,3]"
    crc = format(zlib.crc32(payload.encode()) & 0xFFFFFFFF, "08x")
    assert decode_line(f"{crc} {payload}") is None


def test_snapshot_round_trip(tmp_path):
    path = tmp_path / "state"
    recs = [{"k": i} for i in range(3)]
    write_snapshot(path, recs)
    assert is_durable(path)
    assert path.read_text().startswith(MAGIC)
    assert read_records(path) == (recs, 0)


def test_read_records_missing_file_is_empty(tmp_path):
    assert read_records(tmp_path / "nope") == ([], 0)
    assert not is_durable(tmp_path / "nope")


# ---------------------------------------------------------------------------
# journal: newline-prefixed appends — a torn tail never swallows successors
# ---------------------------------------------------------------------------

def test_journal_torn_tail_terminated_by_next_append(tmp_path):
    path = tmp_path / "state.journal"
    append_journal(path, {"k": 1})
    # simulate a crash mid-append: half of a record lands at the tail
    with open(path, "ab") as f:
        f.write(("\n" + encode_record({"k": 2}))[:12].encode())
    assert read_records(path) == ([{"k": 1}], 1)
    # the NEXT append's newline prefix terminates the torn tail: the new
    # record is intact, the torn one stays dropped
    append_journal(path, {"k": 3})
    assert read_records(path) == ([{"k": 1}, {"k": 3}], 1)


def test_injected_torn_snapshot_persists_truncated_payload(tmp_path):
    path = tmp_path / "state"
    write_snapshot(path, [{"k": 1}])
    # 80% of the payload: the cut lands inside the second record's line
    # (a smaller fraction would tear inside the '#' magic header, which
    # reads as a skipped comment rather than a counted drop)
    plan = FaultPlan([FaultSpec(site="snapshot_write", exc=TornWrite(0.8),
                                times=1)])
    with pytest.raises(TornWrite):
        write_snapshot(path, [{"k": 1}, {"k": 2}], faults=plan)
    # the torn payload clobbered the final path (the modelled crash never
    # reached the rename); recovery drops the torn tail, never raises
    recs, dropped = read_records(path)
    assert recs == [{"k": 1}] and dropped == 1
    # a clean rewrite fully repairs the file
    write_snapshot(path, [{"k": 9}])
    assert read_records(path) == ([{"k": 9}], 0)


def test_durable_store_snapshot_absorbs_journal(tmp_path):
    store = DurableStore(tmp_path / "state")
    store.append({"k": 1})
    store.append({"k": 2})
    assert store.load() == ([{"k": 1}, {"k": 2}], 0)
    store.snapshot([{"k": 3}])
    assert not store.journal_path.exists()
    assert store.load() == ([{"k": 3}], 0)


# ---------------------------------------------------------------------------
# registry recovery: every damaged payload costs warm starts, not startup
# ---------------------------------------------------------------------------

def test_load_decision_cache_garbage_payload_cold_starts(tmp_path):
    reg = ModelRegistry(tmp_path)
    reg.decision_cache_path.parent.mkdir(parents=True, exist_ok=True)
    reg.decision_cache_path.write_bytes(b"garbage {{{ not json")
    rt = AdsalaRuntime()
    assert reg.load_decision_cache(rt) == 0          # no JSONDecodeError
    assert reg.last_recovery["cold_start"] is True
    assert reg.last_recovery["dropped_records"] == 1
    assert rt.cache_len() == 0


def test_load_decision_cache_truncated_legacy_payload(tmp_path):
    reg = ModelRegistry(tmp_path)
    reg.decision_cache_path.parent.mkdir(parents=True, exist_ok=True)
    reg.decision_cache_path.write_text('{"version": 2, "entries": [')
    assert reg.load_decision_cache(AdsalaRuntime()) == 0
    assert reg.last_recovery["cold_start"] is True


def test_load_decision_cache_drops_corrupt_durable_record(tmp_path):
    reg = ModelRegistry(tmp_path)
    rt = AdsalaRuntime()
    rt.register(StubSub())
    for d in ((32, 32, 32), (64, 64, 64)):
        rt.select("gemm", d, 4, backend="b0")
    path = reg.save_decision_cache(rt)
    lines = path.read_text().splitlines()
    lines[2] = "00000000" + lines[2][8:]             # oldest entry: bad CRC
    path.write_text("\n".join(lines) + "\n")
    warm = AdsalaRuntime()
    warm.register(StubSub())
    reg2 = ModelRegistry(tmp_path)
    assert reg2.load_decision_cache(warm) == 1
    assert reg2.last_recovery["dropped_records"] == 1
    assert [tuple(e["dims"]) for e in warm.export_cache()] == [(64, 64, 64)]


def test_journal_replays_after_crash_without_snapshot(tmp_path):
    """Decisions journaled between snapshots survive a crash that never
    reached save_decision_cache — and the journal wins key collisions."""
    reg = ModelRegistry(tmp_path)
    rt = AdsalaRuntime()
    rt.register(StubSub())
    rt.decision_journal = reg.journal_decision
    rt.select("gemm", (32, 32, 32), 4, backend="b0")
    assert not reg.decision_cache_path.exists()      # no snapshot ever ran
    warm = AdsalaRuntime()
    warm.register(StubSub())
    reg2 = ModelRegistry(tmp_path)
    assert reg2.load_decision_cache(warm) == 1
    assert reg2.last_recovery["journal_records"] == 1
    assert warm.peek("gemm", (32, 32, 32), 4, backend="b0") is not None


def test_torn_journal_append_is_counted_not_raised(tmp_path):
    plan = FaultPlan([FaultSpec(site="journal_append", exc=TornWrite(0.5),
                                times=1)])
    reg = ModelRegistry(tmp_path, faults=plan)
    rt = AdsalaRuntime()
    rt.register(StubSub())
    rt.decision_journal = reg.journal_decision
    rt.select("gemm", (32, 32, 32), 4, backend="b0")   # torn append
    rt.select("gemm", (64, 64, 64), 4, backend="b0")   # clean append
    assert rt.stats.journal_failures == 1              # counted, not raised
    warm = AdsalaRuntime()
    warm.register(StubSub())
    reg2 = ModelRegistry(tmp_path)
    assert reg2.load_decision_cache(warm) == 1
    assert reg2.last_recovery["dropped_records"] == 1
    assert [tuple(e["dims"]) for e in warm.export_cache()] == [(64, 64, 64)]


def test_versions_sidecar_tolerates_damage(tmp_path):
    reg = ModelRegistry(tmp_path)
    # legacy plain-JSON sidecar still reads
    reg.versions_path.parent.mkdir(parents=True, exist_ok=True)
    reg.versions_path.write_text(json.dumps({"a.adsala": 2}))
    assert reg.artifact_version("a.adsala") == 2
    # garbage degrades to empty (versions restart; stale caches are then
    # dropped at warm start by the version gate, never replayed wrongly)
    reg.versions_path.write_bytes(b"\x00\xff garbage")
    assert reg.artifact_version("a.adsala") == 0
    # durable snapshot records merge with max()
    write_snapshot(reg.versions_path,
                   [{"versions": {"a.adsala": 3}},
                    {"versions": {"a.adsala": 5, "b.adsala": 1}}])
    assert reg.artifact_version("a.adsala") == 5
    assert reg.artifact_version("b.adsala") == 1


# ---------------------------------------------------------------------------
# import_cache: corrupt entries are counted drops, never exceptions
# ---------------------------------------------------------------------------

def test_import_cache_counts_corrupt_entries():
    rt = AdsalaRuntime()
    valid = {"backend": "b0", "op": "gemm", "dtype_bytes": 4,
             "dims": [32, 32, 32], "knob": {"bm": 64},
             "artifact_version": 0}
    garbage = ["not-a-dict", 17, {"no": "fields"},
               {"backend": "b0", "op": "gemm", "dtype_bytes": "x",
                "dims": [3], "knob": {"bm": 64}},
               {"backend": "b0", "op": "gemm", "dtype_bytes": 4,
                "dims": [32], "knob": "not-a-mapping"}]
    assert rt.import_cache([valid] + garbage) == 1
    assert rt.stats.import_drops_corrupt == len(garbage)
    assert rt.peek("gemm", (32, 32, 32), 4, backend="b0") is not None


def test_import_cache_counts_corrupt_quarantine_records():
    rt = AdsalaRuntime()
    bad_q = {"quarantine": 1, "backend": "b0", "op": "gemm",
             "dtype_bytes": 4, "knob": "not-a-mapping",
             "fallback_knob": {"bm": 64}, "ttl_s": 5.0}
    good_q = {"quarantine": 1, "backend": "b0", "op": "gemm",
              "dtype_bytes": 4, "knob": {"bm": 32},
              "fallback_knob": {"bm": 64}, "ttl_s": 60.0}
    assert rt.import_cache([bad_q, good_q]) == 0     # quarantines aren't
    assert rt.stats.import_drops_corrupt == 1        # decision imports
    assert rt.is_quarantined("gemm", 4, "b0", Knob((("bm", 32),)))
