"""Online feedback loop: hot-swap atomicity under contention, artifact
version round-trips through the decision cache, and the drift-detecting
Retuner (trigger/no-trigger, telemetry keying, blend refit, swap wiring)."""

import threading
import time

import numpy as np
import pytest

from repro.core import AdsalaRuntime, ModelRegistry, install_subroutine
from repro.core.knobs import Knob
from repro.kernels import ops
from repro.serving import (BlasService, Retuner, RetuneConfig, ServeConfig,
                           bucket_key)


class GenSub:
    """Stub whose knob carries its generation — a reader can tell WHICH
    model answered its select."""

    def __init__(self, backend: str, gen: int, op: str = "gemm",
                 dtype_bytes: int = 4) -> None:
        self.backend = backend
        self.op = op
        self.dtype_bytes = dtype_bytes
        self.gen = gen
        self.knob = Knob((("gen", gen),))
        self.artifact_version = gen

    def select(self, dims):
        return self.knob


@pytest.fixture(scope="module")
def tuned():
    """One real tuned artifact (flat-time timer keeps the install fast)."""
    space = ops.knob_space_for("gemm", sizes=(32, 64))
    return install_subroutine(
        "gemm", space, lambda dims, knob: 1e-3, n_samples=12,
        dim_lo=32, dim_hi=64, max_footprint_bytes=1_000_000,
        tune_trials=1, candidates=("LinearRegression",), use_lof=False,
        backend="pallas")


# ---------------------------------------------------------------------------
# hot-swap atomicity
# ---------------------------------------------------------------------------

def test_swap_atomicity_under_contention():
    """N threads hammer select/select_many through a stream of swaps.  The
    contract: once swap() has returned, NO select may answer with an older
    generation — a reader that snapshots the published generation before
    its select must get a knob at least that new.  And nothing deadlocks."""
    rt = AdsalaRuntime(cache_size=64)
    rt.register(GenSub("b0", 0))
    dims_pool = [(32 * i, 32, 32) for i in range(1, 5)]
    published = [0]                  # generation of the last COMPLETED swap
    errors = []
    stop = threading.Event()

    def reader(tid):
        try:
            i = 0
            while not stop.is_set():
                i += 1
                g = published[0]
                if i % 3 == 0:
                    knobs = rt.select_many(
                        [("gemm", d, 4, "b0") for d in dims_pool])
                    for k in knobs:
                        assert k["gen"] >= g, (k["gen"], g)
                else:
                    k = rt.select("gemm", dims_pool[i % 4], 4, backend="b0")
                    assert k["gen"] >= g, (k["gen"], g)
        except Exception as e:   # noqa: BLE001
            errors.append(e)

    threads = [threading.Thread(target=reader, args=(t,)) for t in range(6)]
    for t in threads:
        t.start()
    for gen in range(1, 25):
        rt.swap(GenSub("b0", gen))
        published[0] = gen           # readers starting now must see >= gen
        time.sleep(0.002)
    stop.set()
    for t in threads:
        t.join(timeout=30)
        assert not t.is_alive(), "reader deadlocked across swaps"
    assert not errors, errors[:3]
    s = rt.stats
    assert s.swaps == 24
    # every post-final-swap select answers with the final generation
    assert rt.select("gemm", dims_pool[0], 4, backend="b0")["gen"] == 24


def test_swap_invalidates_only_its_own_subroutine():
    rt = AdsalaRuntime()
    rt.register(GenSub("b0", 1))
    rt.register(GenSub("b1", 1))
    for d in ((32, 32, 32), (64, 32, 32)):
        rt.select("gemm", d, 4, backend="b0")
        rt.select("gemm", d, 4, backend="b1")
    assert rt.swap(GenSub("b0", 2)) == 2
    # b0's decisions are gone, b1's survive untouched
    assert rt.peek("gemm", (32, 32, 32), 4, backend="b0") is None
    assert rt.peek("gemm", (32, 32, 32), 4, backend="b1") is not None
    assert rt.stats.swap_invalidations == 2
    assert rt.select("gemm", (32, 32, 32), 4, backend="b0")["gen"] == 2


def test_register_replacement_also_bumps_epoch():
    """Replacing via register() must not leave stale in-flight or cached
    decisions either (swap() is register-replace + invalidate)."""
    rt = AdsalaRuntime()
    rt.register(GenSub("b0", 1))
    rt.select("gemm", (32, 32, 32), 4, backend="b0")
    rt.register(GenSub("b0", 2))
    # register() does not invalidate the cache (that's swap's contract) —
    # but a cold key must be answered by the new model
    assert rt.select("gemm", (64, 32, 32), 4, backend="b0")["gen"] == 2


# ---------------------------------------------------------------------------
# artifact versioning through the decision cache
# ---------------------------------------------------------------------------

def test_version_bumped_registry_rejects_pre_bump_cache(tmp_path, tuned):
    reg = ModelRegistry(tmp_path)
    reg.save(tuned)                                  # artifact_version 1
    assert tuned.artifact_version == 1
    rt = AdsalaRuntime()
    rt.register(tuned)
    shapes = [(32 * i, 32, 32) for i in range(1, 5)]
    for d in shapes:
        rt.select("gemm", d, 4, backend="pallas")
    reg.save_decision_cache(rt)                      # entries stamped v1

    reg.save(tuned)                                  # bump → 2
    assert tuned.artifact_version == 2
    rt2 = AdsalaRuntime()
    rt2.register(reg.load_all(backend="pallas")[0])  # loads the v2 artifact
    assert reg.load_decision_cache(rt2) == 0         # v1 cache: rejected
    assert rt2.stats.import_drops_version == len(shapes)
    assert rt2.cache_len() == 0

    # the matching-version cache round-trips
    for d in shapes:
        rt2.select("gemm", d, 4, backend="pallas")
    reg.save_decision_cache(rt2)
    rt3 = AdsalaRuntime()
    rt3.register(reg.load_all(backend="pallas")[0])
    assert reg.load_decision_cache(rt3) == len(shapes)
    assert rt3.stats.import_drops_version == 0
    for d in shapes:
        rt3.select("gemm", d, 4, backend="pallas")
    assert rt3.stats.model_evals == 0                # pure warm start


def test_artifact_version_survives_delete_and_reinstall(tmp_path, tuned):
    """versions.json is the authority: deleting the artifact file must not
    reset the counter (a re-install after cleanup must still invalidate
    caches stamped by the deleted generation)."""
    reg = ModelRegistry(tmp_path)
    reg.save(tuned)
    v = tuned.artifact_version
    from repro.core.registry import artifact_name
    (tmp_path / artifact_name(tuned)).unlink()
    reg.save(tuned)
    assert tuned.artifact_version == v + 1


def test_unstamped_artifacts_keep_legacy_cache_semantics(tmp_path):
    """Subroutines never saved through a registry (version 0) interop with
    caches that carry no version — nothing is dropped."""
    rt = AdsalaRuntime()
    rt.register(GenSub("b0", 0))
    rt.select("gemm", (32, 32, 32), 4, backend="b0")
    entries = rt.export_cache()
    assert entries[0]["artifact_version"] == 0
    warm = AdsalaRuntime()
    warm.register(GenSub("b0", 0))
    assert warm.import_cache(entries) == 1
    assert warm.stats.import_drops_version == 0


# ---------------------------------------------------------------------------
# the Retuner
# ---------------------------------------------------------------------------

def drive(rt, ret, dims_pool, measured_fn, *, backend="pallas", items=2):
    """Serve + report one telemetry tick for every pool bucket."""
    for d in dims_pool:
        k = rt.select("gemm", d, 4, backend=backend)
        rt.record_batch("gemm", d, 4, backend, 1,
                        exec_seconds=measured_fn(d, k) * items,
                        exec_items=items)
    return ret.observe()


def test_retuner_no_false_trigger(tuned):
    rt = AdsalaRuntime()
    rt.register(tuned)
    ret = Retuner(rt, config=RetuneConfig(min_samples=2))
    cp = rt.predictor("gemm", 4, backend="pallas")
    pool = [(32, 32, 32), (64, 32, 64), (48, 64, 32)]
    space = tuned.knob_space
    added = drive(rt, ret, pool,
                  lambda d, k: float(cp.predict_times(d)[space.index(k)]))
    assert added == len(pool)
    assert ret.step() == []
    ewma, n = ret.drift("gemm", 4, "pallas")
    assert n == len(pool) and ewma == pytest.approx(0.0, abs=1e-12)
    assert ret.stats.retunes == 0 and ret.stats.drift_events == 0


def test_retuner_detects_drift_and_swaps_without_registry(tuned):
    rt = AdsalaRuntime()
    rt.register(tuned)
    ret = Retuner(rt, config=RetuneConfig(min_samples=3, drift_threshold=0.5,
                                          tune_trials=1))
    cp = rt.predictor("gemm", 4, backend="pallas")
    space = tuned.knob_space
    pool = [(32, 32, 32), (64, 32, 64), (48, 64, 32), (64, 64, 64)]
    drive(rt, ret, pool,
          lambda d, k: 3.0 * float(cp.predict_times(d)[space.index(k)]))
    ewma, _ = ret.drift("gemm", 4, "pallas")
    assert ewma == pytest.approx(2.0, rel=1e-6)      # |3p - p| / p
    swapped = ret.step()
    assert swapped == [("pallas", "gemm", 4)]
    new_sub = rt.subroutine("gemm", 4, backend="pallas")
    assert new_sub is not tuned
    # no registry → local monotonic bump off the old artifact's version
    assert new_sub.artifact_version == tuned.artifact_version + 1
    assert rt.stats.swaps == 1
    assert ret.stats.retunes == 1 and ret.stats.errors == 0
    # state reset: the new model starts with a clean drift signal
    assert ret.drift("gemm", 4, "pallas") == (None, 0)


def test_retuner_telemetry_is_keyed_and_capped(tuned):
    """Re-measuring a bucket REPLACES its sample (stale pre-drift telemetry
    must not feed the refit) and the ring is bounded."""
    rt = AdsalaRuntime()
    rt.register(tuned)
    ret = Retuner(rt, config=RetuneConfig(telemetry_cap=3, min_samples=1,
                                          drift_threshold=1e9))
    pool = [(32 * i, 32, 32) for i in range(1, 6)]       # 5 buckets, cap 3
    drive(rt, ret, pool, lambda d, k: 1e-3)
    st = ret._state[("pallas", "gemm", 4)]
    assert len(st.samples) == 3                          # capped
    # re-measure the newest bucket with a new value: replaced, not appended
    d = pool[-1]
    k = rt.select("gemm", d, 4, backend="pallas")
    rt.record_batch("gemm", d, 4, "pallas", 1,
                    exec_seconds=4e-3, exec_items=2)
    ret.observe()
    assert len(st.samples) == 3
    idx = tuned.knob_space.index(k)
    assert st.samples[(d, idx)] == pytest.approx(2e-3)   # the NEW value


def test_retuner_retune_without_telemetry_raises(tuned):
    rt = AdsalaRuntime()
    rt.register(tuned)
    ret = Retuner(rt)
    with pytest.raises(RuntimeError, match="no telemetry"):
        ret.retune(("pallas", "gemm", 4))


def test_retuner_refit_follows_measured_surface(tuned, tmp_path):
    """After a drift that flips the cost ordering, the refit model's
    decisions must move off the drifted knob, and the swap must be
    bit-identical to a fresh process loading the saved artifact."""
    reg = ModelRegistry(tmp_path)
    reg.save(tuned)
    v_installed = tuned.artifact_version
    rt = AdsalaRuntime()
    rt.register(tuned)
    ret = Retuner(rt, registry=reg,
                  config=RetuneConfig(min_samples=3, drift_threshold=0.5,
                                      tune_trials=1))
    cp = rt.predictor("gemm", 4, backend="pallas")
    space = tuned.knob_space
    pool = [(32, 32, 32), (64, 32, 64), (48, 64, 32), (64, 64, 64)]
    drive(rt, ret, pool,
          lambda d, k: 4.0 * float(cp.predict_times(d)[space.index(k)]))
    assert ret.step() == [("pallas", "gemm", 4)]
    new_sub = rt.subroutine("gemm", 4, backend="pallas")
    assert new_sub.artifact_version == v_installed + 1

    fresh = AdsalaRuntime()
    fresh.register(reg.load_all(backend="pallas")[0])
    live_cp = rt.predictor("gemm", 4, backend="pallas")
    fresh_cp = fresh.predictor("gemm", 4, backend="pallas")
    for d in pool:
        assert np.array_equal(live_cp.predict_times(d),
                              fresh_cp.predict_times(d))
        assert rt.select("gemm", d, 4, backend="pallas") == \
            fresh.select("gemm", d, 4, backend="pallas")


def test_retuner_background_thread_start_stop(tuned):
    rt = AdsalaRuntime()
    rt.register(tuned)
    ret = Retuner(rt, config=RetuneConfig(min_samples=2, drift_threshold=0.5,
                                          interval_s=0.02, tune_trials=1))
    cp = rt.predictor("gemm", 4, backend="pallas")
    space = tuned.knob_space
    pool = [(32, 32, 32), (64, 32, 64), (48, 64, 32)]
    for d in pool:
        k = rt.select("gemm", d, 4, backend="pallas")
        rt.record_batch("gemm", d, 4, "pallas", 1,
                        exec_seconds=3.0 * float(
                            cp.predict_times(d)[space.index(k)]) * 2,
                        exec_items=2)
    ret.start()
    ret.start()                                      # idempotent
    deadline = time.monotonic() + 30
    while ret.stats.retunes == 0 and time.monotonic() < deadline:
        time.sleep(0.01)
    ret.stop()
    ret.stop()                                       # idempotent
    assert ret.stats.retunes >= 1 and ret.stats.errors == 0
    assert rt.stats.swaps >= 1


# ---------------------------------------------------------------------------
# serving integration: queue/exec split + service-managed retuner
# ---------------------------------------------------------------------------

def test_serving_splits_queue_and_exec_time():
    from repro.backends import get_backend
    rt = AdsalaRuntime()
    cfg = ServeConfig(backend="ref", max_batch=8, linger_ms=2.0)
    dims = (48, 32, 40)
    operands = get_backend("ref").make_operands("gemm", dims, np.float32,
                                                seed=0)
    with BlasService(runtime=rt, config=cfg) as svc:
        futs = [svc.submit("gemm", operands) for _ in range(12)]
        for f in futs:
            f.result(timeout=30)
        stats = svc.stats
    assert stats.exec_sum > 0.0 and stats.queue_sum > 0.0
    assert stats.mean_exec_latency > 0.0 and stats.mean_queue_latency > 0.0
    key = bucket_key("gemm", [a.shape for a in operands],
                     [a.dtype for a in operands], "ref")
    backend, op, dtype_bytes, dims_key = key[0], key[1], key[2], key[3]
    b = rt.stats.buckets[(backend, op, dtype_bytes, dims_key)]
    assert b.exec_items == 12
    assert b.exec_seconds > 0.0
    assert b.mean_exec_per_item == pytest.approx(
        b.exec_seconds / b.exec_items)
    # queue time is tracked separately — it must NOT inflate exec time
    assert b.queue_seconds >= 0.0
    assert b.mean_queue >= 0.0


def test_service_starts_and_stops_retuner(tuned):
    rt = AdsalaRuntime()
    rt.register(tuned)
    ret = Retuner(rt, config=RetuneConfig(interval_s=0.05))
    cfg = ServeConfig(backend="ref", max_batch=4, linger_ms=2.0)
    with BlasService(runtime=rt, config=cfg, retuner=ret) as svc:
        assert svc.retuner is ret
        assert ret._thread is not None and ret._thread.is_alive()
    assert ret._thread is None or not ret._thread.is_alive()


# ---------------------------------------------------------------------------
# bounded shutdown: stop() join budget + abandoned-refit accounting
# ---------------------------------------------------------------------------

def test_retuner_stop_abandons_stuck_thread_without_leaking():
    rt = AdsalaRuntime()
    ret = Retuner(rt, config=RetuneConfig(interval_s=60.0))
    release = threading.Event()
    stuck = threading.Thread(target=release.wait, daemon=True)
    stuck.start()
    ret._thread = stuck                 # simulate a thread wedged mid-refit
    t0 = time.monotonic()
    assert ret.stop(timeout=0.2) is False
    assert time.monotonic() - t0 < 2.0  # the join was bounded, not 10 s
    assert ret.stats.abandoned_stops == 1
    # the thread reference is KEPT — abandoned, counted, not leaked
    assert ret._thread is stuck
    release.set()
    assert ret.stop(timeout=5.0) is True
    assert ret._thread is None
    assert ret.stats.abandoned_stops == 1


def test_retuner_stop_counts_each_abandonment():
    rt = AdsalaRuntime()
    ret = Retuner(rt, config=RetuneConfig(interval_s=60.0))
    release = threading.Event()
    stuck = threading.Thread(target=release.wait, daemon=True)
    stuck.start()
    ret._thread = stuck
    assert ret.stop(timeout=0.05) is False
    assert ret.stop(timeout=0.05) is False
    assert ret.stats.abandoned_stops == 2
    release.set()
    stuck.join(timeout=5.0)


class _RecordingRetuner:
    """start()/stop() shim standing in for a Retuner whose refit outlasts
    the service's close budget."""

    def __init__(self, stop_result=True):
        self.stop_result = stop_result
        self.stop_timeouts = []
        self.starts = 0

    def start(self):
        self.starts += 1

    def stop(self, timeout=10.0):
        self.stop_timeouts.append(timeout)
        return self.stop_result


def test_service_close_bounds_retuner_join_by_remaining_budget():
    rt = AdsalaRuntime()
    shim = _RecordingRetuner(stop_result=True)
    svc = BlasService(runtime=rt,
                      config=ServeConfig(backend="ref", workers=1),
                      retuner=shim)
    svc.close(timeout=4.0)
    assert shim.starts == 1
    assert len(shim.stop_timeouts) == 1
    # the join got what was LEFT of the close budget, not a fixed default:
    # bounded above by the caller's timeout, floored at the 0.1 s minimum
    assert 0.1 <= shim.stop_timeouts[0] <= 4.0
    assert svc.stats.retuner_abandoned == 0


def test_service_close_counts_abandoned_retuner():
    rt = AdsalaRuntime()
    shim = _RecordingRetuner(stop_result=False)
    svc = BlasService(runtime=rt,
                      config=ServeConfig(backend="ref", workers=1),
                      retuner=shim)
    svc.close(timeout=2.0)
    assert svc.stats.retuner_abandoned == 1
    # close() stays idempotent; the second call must not re-join the retuner
    svc.close(timeout=2.0)
    assert len(shim.stop_timeouts) == 1
