"""The paper's full loop for one subroutine: install-time calibration with
measured wall-clock → model selection table (paper Table VI) → held-out
speedup statistics (paper Table VII row).

Backend-parameterised — the same harness tunes any registered execution
backend (the repo analogue of the paper's MKL-vs-BLIS generality claim):

    PYTHONPATH=src python examples/autotune_blas.py --op syrk --samples 60
    PYTHONPATH=src python examples/autotune_blas.py --op gemm \\
        --backend pallas --samples 20
"""

import argparse
import time

import numpy as np

from repro.backends import available_backends, get_backend
from repro.core import install_subroutine
from repro.core.features import SUBROUTINE_NDIMS, footprint_words
from repro.core.halton import sample_dims
from repro.core.timing import time_callable


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--op", default="syrk")
    p.add_argument("--backend", default="cpu_blocked",
                   choices=available_backends())
    p.add_argument("--samples", type=int, default=60)
    p.add_argument("--sizes", default="")
    args = p.parse_args()
    op = args.op

    be = get_backend(args.backend)
    if args.sizes:
        sizes = tuple(int(s) for s in args.sizes.split(","))
    else:
        # pallas interpret-mode pays a per-(shape,knob) compile: coarse grid
        sizes = (128, 256) if be.name == "pallas" else (32, 64, 128)
    space = be.knob_space(op, sizes=sizes)
    timer = be.timer_fn(op, np.float32, warmup=0 if be.name != "pallas"
                        else 1, repeats=2)

    sub = install_subroutine(op, space, timer, n_samples=args.samples,
                             dim_lo=32, dim_hi=512,
                             max_footprint_bytes=4_000_000, dtype_bytes=4,
                             tune_trials=3, backend=be.name,
                             progress=lambda i, n: print(
                                 f"  gathered {i}/{n}", end="\r"))
    print(f"\n== [{be.name}] model selection (paper Table VI) — "
          f"best: {sub.model_name}")
    for r in sorted(sub.reports, key=lambda r: -r.estimated_mean_speedup):
        print(f"  {r.name:18s} nrmse={r.normalized_rmse:.2f} "
              f"ideal={r.ideal_mean_speedup:.2f} eval={r.eval_time_us:7.0f}µs "
              f"est={r.estimated_mean_speedup:.2f}")

    # held-out speedup (paper Table VII), through the shared Backend protocol
    default = sub.dataset.knob_space.candidates[
        sub.dataset.default_knob_index()]
    fp = lambda d: footprint_words(op, d) * 4
    test = sample_dims(15, SUBROUTINE_NDIMS[op], lo=32, hi=512,
                       max_footprint_bytes=4_000_000, footprint_fn=fp,
                       seed=777)
    sp = []
    for drow in test:
        dims = tuple(int(v) for v in drow)
        operands = be.prepare(be.make_operands(op, dims, np.float32))
        t0 = time.perf_counter()
        knob = sub.select(dims)
        t_eval = time.perf_counter() - t0
        t_def = time_callable(lambda: be.execute(op, operands, default),
                              warmup=1, repeats=2)
        t_ml = time_callable(lambda: be.execute(op, operands, knob),
                             warmup=1, repeats=2)
        sp.append(t_def / (t_ml + t_eval))
    sp = np.array(sp)
    print(f"== [{be.name}] held-out speedup (paper Table VII): "
          f"mean={sp.mean():.2f} median={np.median(sp):.2f} "
          f"min={sp.min():.2f} max={sp.max():.2f}")


if __name__ == "__main__":
    main()
