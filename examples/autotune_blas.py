"""The paper's full loop for one subroutine: install-time calibration with
measured wall-clock → model selection table (paper Table VI) → held-out
speedup statistics (paper Table VII row).

    PYTHONPATH=src python examples/autotune_blas.py --op syrk --samples 60
"""

import argparse

import numpy as np

from repro.core import install_subroutine
from repro.core.features import SUBROUTINE_NDIMS, footprint_words
from repro.core.halton import sample_dims
from repro.core.timing import time_callable
from repro.kernels.cpu_blocked import make_operands, run_blocked
from repro.kernels.ops import knob_space_for


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--op", default="syrk")
    p.add_argument("--samples", type=int, default=60)
    args = p.parse_args()
    op = args.op

    space = knob_space_for(op, sizes=(32, 64, 128))
    cache = {}

    def timer(dims, knob):
        if cache.get("dims") != dims:
            cache["dims"] = dims
            cache["ops"] = make_operands(op, dims, np.float32)
        return time_callable(lambda: run_blocked(op, cache["ops"], knob),
                             warmup=0, repeats=2)

    sub = install_subroutine(op, space, timer, n_samples=args.samples,
                             dim_lo=32, dim_hi=512,
                             max_footprint_bytes=4_000_000, dtype_bytes=4,
                             tune_trials=3,
                             progress=lambda i, n: print(
                                 f"  gathered {i}/{n}", end="\r"))
    print(f"\n== model selection (paper Table VI) — best: {sub.model_name}")
    for r in sorted(sub.reports, key=lambda r: -r.estimated_mean_speedup):
        print(f"  {r.name:18s} nrmse={r.normalized_rmse:.2f} "
              f"ideal={r.ideal_mean_speedup:.2f} eval={r.eval_time_us:7.0f}µs "
              f"est={r.estimated_mean_speedup:.2f}")

    # held-out speedup (paper Table VII)
    default = sub.dataset.knob_space.candidates[
        sub.dataset.default_knob_index()]
    fp = lambda d: footprint_words(op, d) * 4
    test = sample_dims(15, SUBROUTINE_NDIMS[op], lo=32, hi=512,
                       max_footprint_bytes=4_000_000, footprint_fn=fp,
                       seed=777)
    sp = []
    for drow in test:
        dims = tuple(int(v) for v in drow)
        operands = make_operands(op, dims, np.float32)
        knob = sub.select(dims)
        t_def = time_callable(lambda: run_blocked(op, operands, default),
                              warmup=1, repeats=2)
        t_ml = time_callable(lambda: run_blocked(op, operands, knob),
                             warmup=1, repeats=2)
        sp.append(t_def / t_ml)
    sp = np.array(sp)
    print(f"== held-out speedup (paper Table VII): mean={sp.mean():.2f} "
          f"median={np.median(sp):.2f} min={sp.min():.2f} max={sp.max():.2f}")


if __name__ == "__main__":
    main()
