"""Quickstart: ADSALA in 60 seconds.

Install-time: tune SGEMM's execution config on THIS machine with real
wall-clock timings (through the ``cpu_blocked`` execution backend); runtime:
the library picks the argmin-predicted config per call, memoized across
repeated shapes and keyed by backend.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.backends import get_backend
from repro.core import AdsalaRuntime, install_subroutine
from repro.core.timing import time_callable


def main():
    # 1. install: Halton-sample dims, time every candidate block config,
    #    train + select the ML model by estimated speedup (paper Fig. 1a)
    be = get_backend("cpu_blocked")
    space = be.knob_space("gemm", sizes=(32, 64, 128))
    timer = be.timer_fn("gemm", np.float32, warmup=0, repeats=1)

    print("installing (≈1 min of timing + model selection)...")
    sub = install_subroutine("gemm", space, timer, n_samples=30,
                             dim_lo=32, dim_hi=384,
                             max_footprint_bytes=3_000_000, dtype_bytes=4,
                             candidates=("LinearRegression", "DecisionTree",
                                         "XGBoost"), tune_trials=2,
                             backend=be.name)
    print(f"selected model: {sub.model_name}")
    for r in sub.reports:
        print(f"  {r.name:18s} est_speedup={r.estimated_mean_speedup:.2f} "
              f"eval={r.eval_time_us:.0f}µs")

    # 2. runtime: per-call argmin dispatch with memoization (paper Fig. 1b)
    rt = AdsalaRuntime()
    rt.register(sub)
    default = sub.dataset.knob_space.candidates[
        sub.dataset.default_knob_index()]
    for dims in [(320, 64, 320), (96, 384, 96), (256, 256, 64)]:
        operands = be.make_operands("gemm", dims, np.float32)
        knob = rt.select("gemm", dims, dtype_bytes=4, backend=be.name)
        t_def = time_callable(lambda: be.execute("gemm", operands, default),
                              warmup=1, repeats=3)
        t_ml = time_callable(lambda: be.execute("gemm", operands, knob),
                             warmup=1, repeats=3)
        print(f"dims={dims}: default={t_def*1e3:.2f}ms "
              f"adsala={t_ml*1e3:.2f}ms speedup={t_def/t_ml:.2f}x "
              f"knob={knob.dict}")
    print(f"cache hit rate: {rt.stats.hit_rate:.2f} "
          f"(calls={rt.stats.calls}, by backend: "
          f"{rt.stats.backend_hit_rates})")


if __name__ == "__main__":
    main()
