"""Batched serving example: prefill a batch of prompts, then decode with the
same serve_step the multi-pod dry-run lowers for decode_32k / long_500k.

    PYTHONPATH=src python examples/serve_batched.py --arch rwkv6-1.6b
"""

import argparse

from repro.launch.serve import main as serve_main


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="llama3-8b")
    args = p.parse_args()
    serve_main(["--arch", args.arch, "--smoke", "--requests", "4",
                "--prompt-len", "24", "--max-new", "16",
                "--temperature", "0.8"])


if __name__ == "__main__":
    main()
