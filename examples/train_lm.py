"""End-to-end driver: train a reduced llama3-family model for a few hundred
steps on the synthetic Markov LM task, with checkpointing + auto-resume.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]

The loss must drop well below the uniform floor (ln V ≈ 5.55) — the same
substrate (model zoo + optimizer + data + checkpointing + fault tolerance)
drives the production mesh on real hardware via repro.launch.train.
"""

import argparse
import dataclasses

from repro.checkpoint import Checkpointer
from repro.configs import get_smoke_config
from repro.data import SyntheticLMDataset
from repro.distributed import best_mesh
from repro.launch.train import TrainLoop
from repro.optim import AdamWConfig


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--steps", type=int, default=300)
    p.add_argument("--arch", default="llama3-8b")
    p.add_argument("--ckpt", default="runs/example_train")
    args = p.parse_args()

    cfg = dataclasses.replace(get_smoke_config(args.arch),
                              n_layers=4, d_model=128, n_heads=4, kv_heads=2,
                              d_ff=320, vocab=512)
    ds = SyntheticLMDataset(vocab=cfg.vocab, seq_len=64, global_batch=8)
    loop = TrainLoop(
        cfg=cfg,
        adamw=AdamWConfig(lr=1e-3, total_steps=args.steps, warmup_steps=20),
        mesh=best_mesh(), ckpt=Checkpointer(args.ckpt), dataset=ds,
        ckpt_every=100, log_every=25)
    out = loop.run(args.steps)
    first = out["history"][0]["loss"] if out["history"] else float("nan")
    last = out["history"][-1]["loss"] if out["history"] else float("nan")
    print(f"loss: {first:.3f} → {last:.3f} over {out['final_step']} steps")
    assert last < first, "training did not reduce the loss"


if __name__ == "__main__":
    main()
