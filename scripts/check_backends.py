#!/usr/bin/env python
"""CI/tooling smoke check for the multi-backend execution layer.

Enumerates every registered backend, runs one tiny instance of each of its
ops through the shared ``Backend`` protocol, and compares the result against
the ``ref`` backend (pure-jnp oracle).  Exits nonzero on any mismatch or
execution failure — runnable in CI and locally:

    PYTHONPATH=src python scripts/check_backends.py
    PYTHONPATH=src python scripts/check_backends.py --backends pallas,ref
"""

from __future__ import annotations

import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np  # noqa: E402

from repro.backends import available_backends, get_backend  # noqa: E402

#: tiny, deliberately non-block-aligned dims (exercise the padding paths)
DIMS = {"gemm": (48, 32, 40), "symm": (48, 40), "syrk": (48, 32),
        "syr2k": (48, 32), "trmm": (48, 40), "trsm": (48, 40)}

REL_TOL = 5e-4   # float32 accumulation-order differences across backends


def rel_err(got, want) -> float:
    got = np.asarray(got, np.float64)
    want = np.asarray(want, np.float64)
    return float(np.max(np.abs(got - want)) /
                 (np.max(np.abs(want)) + 1e-9))


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--backends", default="",
                   help="comma list; default = all registered")
    p.add_argument("--tol", type=float, default=REL_TOL)
    args = p.parse_args(argv)

    names = tuple(b for b in args.backends.split(",") if b) \
        or available_backends()
    unknown = [n for n in names if n not in available_backends()]
    if unknown:
        print(f"[check_backends] unknown backend(s) {unknown}; "
              f"registered: {', '.join(available_backends())}")
        return 2
    ref = get_backend("ref")
    failures = 0
    for name in names:
        be = get_backend(name)
        if not be.is_available():
            print(f"[check_backends] {name}: SKIP (unavailable on host)")
            continue
        for op in be.ops():
            dims = DIMS[op]
            # same seed everywhere → identical problem instance per backend
            operands = be.make_operands(op, dims, np.float32, seed=0)
            want = np.asarray(ref.execute(op, operands))
            try:
                got = np.asarray(be.execute(op, be.prepare(operands),
                                            be.default_knob(op)))
            except Exception as e:   # noqa: BLE001
                print(f"[check_backends] {name}:{op} ERROR "
                      f"{type(e).__name__}: {e}")
                failures += 1
                continue
            err = rel_err(got, want)
            ok = got.shape == want.shape and err < args.tol
            print(f"[check_backends] {name}:{op} dims={dims} "
                  f"relerr={err:.2e} {'ok' if ok else 'MISMATCH'}")
            failures += 0 if ok else 1
    if failures:
        print(f"[check_backends] FAILED: {failures} mismatch(es)")
        return 1
    print(f"[check_backends] all backends match ref ({', '.join(names)})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
