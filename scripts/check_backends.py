#!/usr/bin/env python
"""CI/tooling smoke check for the multi-backend execution layer.

Thin CLI wrapper over :mod:`repro.backends.conformance` — the same harness
the pytest suite (``tests/test_backend_conformance.py``) parametrizes over.
Enumerates every registered backend, runs each of its ops (single and
stacked) in both dtypes against the float64 numpy oracle, and exits nonzero
on any mismatch or execution failure:

    PYTHONPATH=src python scripts/check_backends.py
    PYTHONPATH=src python scripts/check_backends.py --backends pallas,ref
"""

from __future__ import annotations

import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np  # noqa: E402

from repro.backends import available_backends  # noqa: E402
from repro.backends.conformance import run_conformance  # noqa: E402


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--backends", default="",
                   help="comma list; default = all registered")
    p.add_argument("--tol", type=float, default=None,
                   help="override the per-dtype tolerance for every cell")
    p.add_argument("--stacked-width", type=int, default=3,
                   help="also check execute_stacked at this width (0 = off)")
    args = p.parse_args(argv)

    names = tuple(b for b in args.backends.split(",") if b) \
        or available_backends()
    unknown = [n for n in names if n not in available_backends()]
    if unknown:
        print(f"[check_backends] unknown backend(s) {unknown}; "
              f"registered: {', '.join(available_backends())}")
        return 2
    results = run_conformance(names, tol=args.tol,
                              dtypes=(np.float32, np.float64),
                              stacked_width=args.stacked_width)
    failures = 0
    for r in results:
        print(f"[check_backends] {r.line()}")
        if not (r.ok or r.skipped):
            failures += 1
    if failures:
        print(f"[check_backends] FAILED: {failures} mismatch(es)")
        return 1
    print(f"[check_backends] all backends match the oracle "
          f"({', '.join(names)})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
