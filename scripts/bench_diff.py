#!/usr/bin/env python
"""CI gate for the committed perf trajectories.

Diffs freshly measured dimensionless metrics against the ``smoke_baseline``
of the newest entry in each committed trajectory file.  Only speedup
*ratios* (or exact structural counts) are compared — both sides of every
ratio are measured on the same host in the same run, so the gate is
meaningful on CI hardware that has nothing in common with the box that
produced the committed numbers.

Three trajectories:

  * ``BENCH_decision.json`` (always gated): per-family cold-eval speedup,
    the cached per-call path speedup (wide gate + absolute floor), and the
    batched-selection speedup.  Fails on a >``--tolerance`` regression.
  * ``BENCH_serving.json`` (gated when ``--serving-fresh`` is given): the
    batched/unbatched throughput ratio.  On hosts with fewer than 3 cores
    the gate is demoted to a warning — the ratio is GIL-scheduling-flaky
    there (same low-core guard as serve_bench itself).
  * ``BENCH_kernels.json`` (gated when ``--kernels-fresh`` is given): the
    zero-copy execution contract — structural, deterministic metrics
    (host-side pad/slice op counts must be exactly zero; the tri_packed
    grid-slot saving must not shrink), so this gate is immune to timing
    jitter.
  * ``BENCH_model.json`` (gated when ``--model-fresh`` is given): the
    ADSALA-dispatched model-serving contract — routed forward/prefill/
    decode must be bit-identical to the plain matmul path, prewarmed
    serving must pay exactly zero runtime model evaluations, and the
    harvested decision-key count must match the committed baseline (a
    mismatch means the model's GEMM call-site set changed — re-record).
    All deterministic, immune to timing jitter.
  * ``BENCH_retune.json`` (gated when ``--retune-fresh`` is given): the
    online-feedback-loop contract — drift must be detected, the calm phase
    must NOT trigger, the refit must swap in with zero stale-knob
    selections, post-swap decisions must be bit-identical to a fresh
    process loading the retuned artifact, and the version-bumped registry
    must reject the pre-swap decision cache.  All structural/deterministic
    (synthetic cost surface, no wall clock); only the p50 cost-recovery
    ratio gets the standard tolerance gate.
  * ``BENCH_chaos.json`` (gated when ``--chaos-fresh`` is given): the
    fault-injection resilience contract — every submitted future resolves
    (zero hung), crash storms degrade to bit-identical ref results, a
    poisoned knob is quarantined and recovers after its TTL, worker deaths
    lose no requests, artifact-load faults stay isolated, a failed refit
    survives and completes on the next step, an over-budget rung is
    skipped outright (and the gated ladder beats the ungated one on wall
    clock), overload sheds at submit, and brownout serves with zero model
    evals.  All structural flags compared exact (the scenarios are seeded
    and deterministic) except the budget-ladder wall-clock ratio, which
    gets a wide same-host floor.
  * ``BENCH_recovery.json`` (gated when ``--recovery-fresh`` is given):
    the crash-recovery contract — a process SIGKILLed mid-snapshot
    recovers the snapshot+journal union with zero lost futures and zero
    model evals on recovered shapes, torn journal appends and corrupt/
    garbage snapshot records are dropped with exact counts, and an open
    knob quarantine survives the crash.  All structural, compared exact.
  * ``BENCH_fleet.json`` (gated when ``--fleet-fresh`` is given): the
    multi-process fleet contract — a member added to a running fleet
    hydrates from the shared decision journal and serves the already-
    decided shapes with exactly ZERO model evaluations, the fingerprint
    resolver picks the exact arch slug, and the membership roster sees
    every executor (all structural, compared exact).  The fleet/single
    throughput ratio gets the standard tolerance gate, demoted to a
    warning on hosts below 3 cores — with no spare core there is no
    process parallelism for the fleet to win (same guard as the serving
    gate).

    PYTHONPATH=src python scripts/bench_diff.py
    PYTHONPATH=src python scripts/bench_diff.py --fresh /tmp/smoke.json \
        --serving-fresh /tmp/serving.json --kernels-fresh /tmp/kernels.json \
        --model-fresh /tmp/model.json
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO_ROOT / "src"))
sys.path.insert(0, str(REPO_ROOT / "benchmarks"))

BENCH_PATH = REPO_ROOT / "BENCH_decision.json"
SERVING_PATH = REPO_ROOT / "BENCH_serving.json"
KERNELS_PATH = REPO_ROOT / "BENCH_kernels.json"
MODEL_PATH = REPO_ROOT / "BENCH_model.json"
RETUNE_PATH = REPO_ROOT / "BENCH_retune.json"
CHAOS_PATH = REPO_ROOT / "BENCH_chaos.json"
RECOVERY_PATH = REPO_ROOT / "BENCH_recovery.json"
FLEET_PATH = REPO_ROOT / "BENCH_fleet.json"

#: summary-level ratios under the standard (--tolerance) gate
GATED_SUMMARY = ("cold_median_speedup", "batch_speedup")

#: the cached per-call ratio is measured against the frozen PR-2 runtime,
#: whose locked hit path is GIL-scheduling-sensitive — the ratio has a ~3x
#: run-to-run spread on small hosts.  It gets a wide relative gate plus an
#: absolute floor: losing the lock-free hit path (the regression this
#: metric exists to catch) drops it well below 3x.
HIT_METRIC = "hit_call_path_speedup"
HIT_TOLERANCE = 0.75
HIT_FLOOR = 3.0


#: how to (re)generate each trajectory's committed baseline
_RECORDERS = {"decision": "benchmarks/decision_bench.py (full mode)",
              "serving": "benchmarks/serve_bench.py --record <entry>",
              "kernels": "benchmarks/kernel_bench.py --record <entry>",
              "model": "benchmarks/model_bench.py --record <entry>",
              "retune": "benchmarks/retune_bench.py --smoke --record "
                        "<entry>",
              "chaos": "benchmarks/chaos_bench.py --smoke --record "
                       "<entry>",
              "recovery": "benchmarks/recovery_bench.py --smoke --record "
                          "<entry>",
              "fleet": "benchmarks/fleet_bench.py --smoke --record "
                       "<entry>"}


def committed_baseline(path: Path) -> tuple[str, dict]:
    """(entry id, smoke_baseline) of the newest committed entry that has
    one (entries preserve insertion order; the migrated pr3 entry predates
    smoke baselines)."""
    payload = json.loads(path.read_text())
    entries = payload.get("entries", {})
    for entry_id in reversed(list(entries)):
        base = entries[entry_id].get("smoke_baseline")
        if base is not None:
            return entry_id, base
    hint = _RECORDERS.get(payload.get("bench"),
                          "the matching benchmark's --record mode")
    raise SystemExit(f"{path}: no entry carries a smoke_baseline — run "
                     f"{hint} first")


def fresh_metrics(fresh_json: Path | None) -> dict:
    """Fresh smoke metrics: from a pre-generated ``--json`` file, or by
    running the smoke suite in-process."""
    if fresh_json is not None:
        data = json.loads(fresh_json.read_text())
        return {"summary": data["summary"],
                "cold_speedups": {f: r["speedup"]
                                  for f, r in data["cold_model_eval"].items()}}
    import decision_bench
    cold, _hit, _batch, summary = decision_bench.run_suite(
        ["LinearRegression", "DecisionTree", "KNN"], sizes=(32, 64),
        n_samples=10, runs=3, inner=200, cold_inner=30)
    return {"summary": summary,
            "cold_speedups": {f: r["speedup"] for f, r in cold.items()}}


def gate_serving(fresh_json: Path, bench: Path, tolerance: float,
                 failures: list) -> None:
    """Batched/unbatched throughput ratio vs the committed trajectory;
    warn-only on low-core hosts (serve_bench's own guard, recorded in the
    fresh summary so the two guards cannot drift; cpu-count fallback for
    summaries predating the flag)."""
    import os
    entry_id, base = committed_baseline(bench)
    fresh = json.loads(fresh_json.read_text())["summary"]
    committed = base.get("batched_speedup")
    measured = fresh.get("batched_speedup")
    if committed is None or measured is None:
        return
    low_core = fresh.get("low_core")
    if low_core is None:
        low_core = (os.cpu_count() or 1) < 3
    bar = committed * (1.0 - tolerance)
    ok = measured >= bar
    mark = "ok " if ok else ("WRN" if low_core else "REG")
    print(f"[bench_diff] {mark} serving.batched_speedup: committed "
          f"{committed:.2f}x, fresh {measured:.2f}x (floor {bar:.2f}x)"
          f"{' — low-core host, advisory only' if low_core and not ok else ''}")
    if not ok and not low_core:
        failures.append(f"serving.batched_speedup (vs {entry_id})")


def gate_kernels(fresh_json: Path, bench: Path, tolerance: float,
                 failures: list) -> None:
    """Zero-copy structural contract: exact-zero host-side pad/slice counts
    and non-shrinking packed-grid slot savings.  Deterministic — any drift
    is a code change, not noise."""
    entry_id, base = committed_baseline(bench)
    data = json.loads(fresh_json.read_text())
    fresh = data.get("smoke_baseline") or data["summary"]
    copies = fresh.get("host_copy_ops", {})
    for op, count in sorted(copies.items()):
        ok = count == 0
        print(f"[bench_diff] {'ok ' if ok else 'REG'} kernels.copy_ops.{op}: "
              f"{count} (must be 0)")
        if not ok:
            failures.append(f"kernels.copy_ops.{op}")
    for op, committed in sorted(base.get("packed_slot_ratio", {}).items()):
        measured = fresh.get("packed_slot_ratio", {}).get(op)
        if measured is None:
            continue
        bar = committed * (1.0 - tolerance)
        ok = measured >= bar
        print(f"[bench_diff] {'ok ' if ok else 'REG'} "
              f"kernels.packed_slot_ratio.{op}: committed {committed:.2f}x, "
              f"fresh {measured:.2f}x (floor {bar:.2f}x)")
        if not ok:
            failures.append(f"kernels.packed_slot_ratio.{op} "
                            f"(vs {entry_id})")


def gate_model(fresh_json: Path, bench: Path, failures: list) -> None:
    """ADSALA-dispatched serving contract: routed execution must be
    bit-identical, prewarmed serving must pay zero runtime model evals, and
    the harvested key set must match the committed baseline.  All
    deterministic — any drift is a code change, not noise."""
    entry_id, base = committed_baseline(bench)
    data = json.loads(fresh_json.read_text())
    fresh = data.get("smoke_baseline") or data["summary"]

    bit = fresh.get("routed_bit_identical")
    print(f"[bench_diff] {'ok ' if bit else 'REG'} "
          f"model.routed_bit_identical: {bit} (must be True)")
    if not bit:
        failures.append("model.routed_bit_identical")

    evals = fresh.get("prewarm_model_evals")
    ok = evals == 0
    print(f"[bench_diff] {'ok ' if ok else 'REG'} "
          f"model.prewarm_model_evals: {evals} (must be 0)")
    if not ok:
        failures.append("model.prewarm_model_evals")

    cold = fresh.get("cold_model_evals")
    if cold is not None:
        ok = cold > 0
        print(f"[bench_diff] {'ok ' if ok else 'REG'} "
              f"model.cold_model_evals: {cold} (must be >0 — otherwise the "
              f"prewarm gate is vacuous)")
        if not ok:
            failures.append("model.cold_model_evals")

    committed = base.get("harvested_keys")
    measured = fresh.get("harvested_keys")
    if committed is not None and measured is not None:
        ok = measured == committed
        print(f"[bench_diff] {'ok ' if ok else 'REG'} model.harvested_keys: "
              f"committed {committed}, fresh {measured} (exact; a change "
              f"means the GEMM call-site set moved — re-record)")
        if not ok:
            failures.append(f"model.harvested_keys (vs {entry_id})")


def gate_retune(fresh_json: Path, bench: Path, tolerance: float,
                failures: list) -> None:
    """Online-feedback-loop contract: structural flags exact, the p50
    cost-recovery ratio under the committed-baseline tolerance gate.  The
    scenario is a synthetic cost surface — deterministic on any host."""
    entry_id, base = committed_baseline(bench)
    data = json.loads(fresh_json.read_text())
    fresh = data.get("smoke_baseline") or data["summary"]

    structural = (("drift_detected", True), ("no_false_trigger", True),
                  ("retuned", True), ("post_swap_stale_selections", 0),
                  ("swap_bit_identical", True),
                  ("version_mismatch_rejected", True), ("retune_errors", 0))
    for key, want in structural:
        got = fresh.get(key)
        ok = got == want
        print(f"[bench_diff] {'ok ' if ok else 'REG'} retune.{key}: "
              f"{got!r} (must be {want!r})")
        if not ok:
            failures.append(f"retune.{key}")

    committed = base.get("recovery_p50")
    measured = fresh.get("recovery_p50")
    if committed is not None and measured is not None:
        bar = committed * (1.0 - tolerance)
        ok = measured >= bar
        print(f"[bench_diff] {'ok ' if ok else 'REG'} retune.recovery_p50: "
              f"committed {committed:.2f}x, fresh {measured:.2f}x "
              f"(floor {bar:.2f}x)")
        if not ok:
            failures.append(f"retune.recovery_p50 (vs {entry_id})")


def gate_chaos(fresh_json: Path, bench: Path, failures: list) -> None:
    """Fault-injection resilience contract: every structural flag of the
    chaos scenarios compared EXACT against the bench's own pass criteria
    (the committed entry is provenance, not a tolerance baseline — the
    scenarios are seeded and deterministic, so any drift is a code change)."""
    import chaos_bench
    entry_id, _base = committed_baseline(bench)
    data = json.loads(fresh_json.read_text())
    fresh = data.get("smoke_baseline") or data["summary"]
    for key, want in chaos_bench.STRUCTURAL:
        got = fresh.get(key)
        ok = got == want
        print(f"[bench_diff] {'ok ' if ok else 'REG'} chaos.{key}: "
              f"{got!r} (must be {want!r})")
        if not ok:
            failures.append(f"chaos.{key} (vs {entry_id})")
    for key in ("crash_storm_fallback_executions", "worker_respawns",
                "brownout_batches", "brownout_control_evals"):
        got = fresh.get(key, 0)
        ok = got >= 1
        print(f"[bench_diff] {'ok ' if ok else 'REG'} chaos.{key}: "
              f"{got} (must be >=1)")
        if not ok:
            failures.append(f"chaos.{key}")
    speedup = fresh.get("budget_ladder_speedup")
    if speedup is not None:
        floor = chaos_bench.SPEEDUP_FLOOR
        ok = speedup >= floor
        print(f"[bench_diff] {'ok ' if ok else 'REG'} "
              f"chaos.budget_ladder_speedup: {speedup:.2f}x "
              f"(floor {floor:.2f}x — the gated ladder must beat the "
              f"ungated one on a dead rung)")
        if not ok:
            failures.append("chaos.budget_ladder_speedup")


def gate_recovery(fresh_json: Path, bench: Path, failures: list) -> None:
    """Crash-recovery contract: every structural flag of the recovery
    scenarios compared EXACT against the bench's own pass criteria — zero
    lost futures, zero model evals on recovered shapes, exact torn/corrupt
    record drop counts.  Deterministic; any drift is a code change."""
    import recovery_bench
    entry_id, _base = committed_baseline(bench)
    data = json.loads(fresh_json.read_text())
    fresh = data.get("smoke_baseline") or data["summary"]
    for key, want in recovery_bench.STRUCTURAL:
        got = fresh.get(key)
        ok = got == want
        print(f"[bench_diff] {'ok ' if ok else 'REG'} recovery.{key}: "
              f"{got!r} (must be {want!r})")
        if not ok:
            failures.append(f"recovery.{key} (vs {entry_id})")
    for key, want in (("sigkill_snapshot_records",
                       len(recovery_bench.SNAP_SHAPES)),
                      ("sigkill_journal_records",
                       len(recovery_bench.JOURNAL_SHAPES) + 1)):
        got = fresh.get(key)
        ok = got == want
        print(f"[bench_diff] {'ok ' if ok else 'REG'} recovery.{key}: "
              f"{got!r} (must be {want!r})")
        if not ok:
            failures.append(f"recovery.{key}")


def gate_fleet(fresh_json: Path, bench: Path, tolerance: float,
               failures: list) -> None:
    """Multi-process fleet contract: the warm-join structural flags (exact;
    the scenario is deterministic — a newcomer hydrated from the shared
    journal evaluates zero models, or the coherence path broke) plus the
    fleet/single throughput ratio under the committed-baseline tolerance
    gate, warn-only below 3 cores (no spare core means no process
    parallelism to win — same guard as the serving gate)."""
    import os

    import fleet_bench
    entry_id, base = committed_baseline(bench)
    data = json.loads(fresh_json.read_text())
    fresh = data.get("smoke_baseline") or data["summary"]
    for key, want in fleet_bench.STRUCTURAL:
        got = fresh.get(key)
        ok = got == want
        print(f"[bench_diff] {'ok ' if ok else 'REG'} fleet.{key}: "
              f"{got!r} (must be {want!r})")
        if not ok:
            failures.append(f"fleet.{key} (vs {entry_id})")
    committed = base.get("fleet_ratio")
    measured = fresh.get("fleet_ratio")
    if committed is None or measured is None:
        return
    low_core = fresh.get("low_core")
    if low_core is None:
        low_core = (os.cpu_count() or 1) < 3
    bar = committed * (1.0 - tolerance)
    ok = measured >= bar
    mark = "ok " if ok else ("WRN" if low_core else "REG")
    print(f"[bench_diff] {mark} fleet.fleet_ratio: committed "
          f"{committed:.2f}x, fresh {measured:.2f}x (floor {bar:.2f}x)"
          f"{' — low-core host, advisory only' if low_core and not ok else ''}")
    if not ok and not low_core:
        failures.append(f"fleet.fleet_ratio (vs {entry_id})")


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--bench", type=Path, default=BENCH_PATH,
                   help="committed decision trajectory file")
    p.add_argument("--fresh", type=Path, default=None,
                   help="pre-generated smoke metrics JSON "
                        "(decision_bench --smoke --json PATH); default: "
                        "run the smoke suite now")
    p.add_argument("--serving-fresh", type=Path, default=None,
                   help="fresh serving metrics (serve_bench --json PATH); "
                        "gates BENCH_serving.json when given")
    p.add_argument("--serving-bench", type=Path, default=SERVING_PATH,
                   help="committed serving trajectory file")
    p.add_argument("--kernels-fresh", type=Path, default=None,
                   help="fresh kernel metrics (kernel_bench --smoke --json "
                        "PATH); gates BENCH_kernels.json when given")
    p.add_argument("--kernels-bench", type=Path, default=KERNELS_PATH,
                   help="committed kernel trajectory file")
    p.add_argument("--model-fresh", type=Path, default=None,
                   help="fresh model-serving metrics (model_bench --smoke "
                        "--json PATH); gates BENCH_model.json when given")
    p.add_argument("--model-bench", type=Path, default=MODEL_PATH,
                   help="committed model-serving trajectory file")
    p.add_argument("--retune-fresh", type=Path, default=None,
                   help="fresh online-retune metrics (retune_bench --smoke "
                        "--json PATH); gates BENCH_retune.json when given")
    p.add_argument("--retune-bench", type=Path, default=RETUNE_PATH,
                   help="committed online-retune trajectory file")
    p.add_argument("--chaos-fresh", type=Path, default=None,
                   help="fresh chaos metrics (chaos_bench --smoke --json "
                        "PATH); gates BENCH_chaos.json when given")
    p.add_argument("--chaos-bench", type=Path, default=CHAOS_PATH,
                   help="committed chaos trajectory file")
    p.add_argument("--recovery-fresh", type=Path, default=None,
                   help="fresh crash-recovery metrics (recovery_bench "
                        "--smoke --json PATH); gates BENCH_recovery.json "
                        "when given")
    p.add_argument("--recovery-bench", type=Path, default=RECOVERY_PATH,
                   help="committed crash-recovery trajectory file")
    p.add_argument("--fleet-fresh", type=Path, default=None,
                   help="fresh fleet metrics (fleet_bench --smoke --json "
                        "PATH); gates BENCH_fleet.json when given")
    p.add_argument("--fleet-bench", type=Path, default=FLEET_PATH,
                   help="committed fleet trajectory file")
    p.add_argument("--tolerance", type=float, default=0.25,
                   help="allowed fractional regression per metric")
    args = p.parse_args(argv)

    entry_id, base = committed_baseline(args.bench)
    fresh = fresh_metrics(args.fresh)
    floor = 1.0 - args.tolerance

    failures = []

    def check(name: str, committed, measured, metric_floor=None) -> None:
        if committed is None or measured is None:
            return
        bar = committed * floor if metric_floor is None else metric_floor
        ok = measured >= bar
        mark = "ok " if ok else "REG"
        print(f"[bench_diff] {mark} {name}: committed {committed:.2f}x, "
              f"fresh {measured:.2f}x (floor {bar:.2f}x)")
        if not ok:
            failures.append(name)

    for key in GATED_SUMMARY:
        check(f"summary.{key}", base["summary"].get(key),
              fresh["summary"].get(key))
    hit = base["summary"].get(HIT_METRIC)
    if hit is not None:
        check(f"summary.{HIT_METRIC}", hit, fresh["summary"].get(HIT_METRIC),
              metric_floor=max(HIT_FLOOR, hit * (1.0 - HIT_TOLERANCE)))
    for fam, committed in base.get("cold_speedups", {}).items():
        check(f"cold.{fam}", committed, fresh["cold_speedups"].get(fam))

    if args.serving_fresh is not None:
        gate_serving(args.serving_fresh, args.serving_bench,
                     args.tolerance, failures)
    if args.kernels_fresh is not None:
        gate_kernels(args.kernels_fresh, args.kernels_bench,
                     args.tolerance, failures)
    if args.model_fresh is not None:
        gate_model(args.model_fresh, args.model_bench, failures)
    if args.retune_fresh is not None:
        gate_retune(args.retune_fresh, args.retune_bench,
                    args.tolerance, failures)
    if args.chaos_fresh is not None:
        gate_chaos(args.chaos_fresh, args.chaos_bench, failures)
    if args.recovery_fresh is not None:
        gate_recovery(args.recovery_fresh, args.recovery_bench, failures)
    if args.fleet_fresh is not None:
        gate_fleet(args.fleet_fresh, args.fleet_bench,
                   args.tolerance, failures)

    if failures:
        print(f"[bench_diff] FAILED vs entry {entry_id!r}: "
              f"{', '.join(failures)} regressed >"
              f"{args.tolerance:.0%}")
        return 1
    print(f"[bench_diff] OK — no metric regressed >{args.tolerance:.0%} "
          f"vs entry {entry_id!r}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
