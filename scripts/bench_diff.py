#!/usr/bin/env python
"""CI gate for the decision-path perf trajectory.

Runs the decision bench's smoke configuration fresh and diffs its
dimensionless metrics against the ``smoke_baseline`` of the newest entry in
the committed ``BENCH_decision.json``.  Only speedup *ratios* are compared —
both sides of every ratio are measured on the same host in the same run, so
the gate is meaningful on CI hardware that has nothing in common with the
box that produced the committed numbers.

Fails (exit 1) when any gated metric regresses by more than ``--tolerance``
(default 25%):

  * per-family cold-eval speedup (compiled fast path vs reference path),
  * the cached per-call path speedup (select_or_default vs the frozen PR-2
    runtime),
  * the batched-selection speedup (select_many vs N selects).

    PYTHONPATH=src python scripts/bench_diff.py
    PYTHONPATH=src python scripts/bench_diff.py --fresh /tmp/smoke.json
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO_ROOT / "src"))
sys.path.insert(0, str(REPO_ROOT / "benchmarks"))

BENCH_PATH = REPO_ROOT / "BENCH_decision.json"

#: summary-level ratios under the standard (--tolerance) gate
GATED_SUMMARY = ("cold_median_speedup", "batch_speedup")

#: the cached per-call ratio is measured against the frozen PR-2 runtime,
#: whose locked hit path is GIL-scheduling-sensitive — the ratio has a ~3x
#: run-to-run spread on small hosts.  It gets a wide relative gate plus an
#: absolute floor: losing the lock-free hit path (the regression this
#: metric exists to catch) drops it well below 3x.
HIT_METRIC = "hit_call_path_speedup"
HIT_TOLERANCE = 0.75
HIT_FLOOR = 3.0


def committed_baseline(path: Path) -> tuple[str, dict]:
    """(entry id, smoke_baseline) of the newest committed entry that has
    one (entries preserve insertion order; the migrated pr3 entry predates
    smoke baselines)."""
    payload = json.loads(path.read_text())
    entries = payload.get("entries", {})
    for entry_id in reversed(list(entries)):
        base = entries[entry_id].get("smoke_baseline")
        if base is not None:
            return entry_id, base
    raise SystemExit(f"{path}: no entry carries a smoke_baseline — run "
                     "benchmarks/decision_bench.py (full mode) first")


def fresh_metrics(fresh_json: Path | None) -> dict:
    """Fresh smoke metrics: from a pre-generated ``--json`` file, or by
    running the smoke suite in-process."""
    if fresh_json is not None:
        data = json.loads(fresh_json.read_text())
        return {"summary": data["summary"],
                "cold_speedups": {f: r["speedup"]
                                  for f, r in data["cold_model_eval"].items()}}
    import decision_bench
    cold, _hit, _batch, summary = decision_bench.run_suite(
        ["LinearRegression", "DecisionTree", "KNN"], sizes=(32, 64),
        n_samples=10, runs=3, inner=200, cold_inner=30)
    return {"summary": summary,
            "cold_speedups": {f: r["speedup"] for f, r in cold.items()}}


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--bench", type=Path, default=BENCH_PATH,
                   help="committed trajectory file")
    p.add_argument("--fresh", type=Path, default=None,
                   help="pre-generated smoke metrics JSON "
                        "(decision_bench --smoke --json PATH); default: "
                        "run the smoke suite now")
    p.add_argument("--tolerance", type=float, default=0.25,
                   help="allowed fractional regression per metric")
    args = p.parse_args(argv)

    entry_id, base = committed_baseline(args.bench)
    fresh = fresh_metrics(args.fresh)
    floor = 1.0 - args.tolerance

    failures = []

    def check(name: str, committed, measured, metric_floor=None) -> None:
        if committed is None or measured is None:
            return
        bar = committed * floor if metric_floor is None else metric_floor
        ok = measured >= bar
        mark = "ok " if ok else "REG"
        print(f"[bench_diff] {mark} {name}: committed {committed:.2f}x, "
              f"fresh {measured:.2f}x (floor {bar:.2f}x)")
        if not ok:
            failures.append(name)

    for key in GATED_SUMMARY:
        check(f"summary.{key}", base["summary"].get(key),
              fresh["summary"].get(key))
    hit = base["summary"].get(HIT_METRIC)
    if hit is not None:
        check(f"summary.{HIT_METRIC}", hit, fresh["summary"].get(HIT_METRIC),
              metric_floor=max(HIT_FLOOR, hit * (1.0 - HIT_TOLERANCE)))
    for fam, committed in base.get("cold_speedups", {}).items():
        check(f"cold.{fam}", committed, fresh["cold_speedups"].get(fam))

    if failures:
        print(f"[bench_diff] FAILED vs entry {entry_id!r}: "
              f"{', '.join(failures)} regressed >"
              f"{args.tolerance:.0%}")
        return 1
    print(f"[bench_diff] OK — no metric regressed >{args.tolerance:.0%} "
          f"vs entry {entry_id!r}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
