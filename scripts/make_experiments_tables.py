"""Render EXPERIMENTS.md tables from runs/ artifacts (dry-run JSONs +
calibration reports + benchmark JSONs).  Prints markdown to stdout."""

import json
import sys
from pathlib import Path

RUNS = Path(__file__).resolve().parents[1] / "runs"


def dryrun_records():
    recs = []
    for f in sorted((RUNS / "dryrun").glob("*.json")):
        r = json.loads(f.read_text())
        if not r.get("tag"):
            recs.append(r)
    return recs


def fmt_bytes(b):
    return f"{b/1e9:.2f}"


def dryrun_table():
    print("| arch | shape | mesh | chips | compile s | peak GB/dev | "
          "HLO GF/dev (corr.) | coll GB/dev | #coll ops |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in dryrun_records():
        if r["status"] == "skipped":
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | "
                  f"skipped: {r['reason'][:40]}… | — | — | — |")
            continue
        c = r["cost"]
        print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['chips']} | "
              f"{r['compile_s']} | {fmt_bytes(r['memory']['peak_bytes'])} | "
              f"{c['flops_per_device']/1e9:.0f} | "
              f"{c['collective_bytes']/1e9:.2f} | "
              f"{r['collectives'].get('total_count', 0)} |")


def roofline_table():
    print("| arch | shape | mesh | t_comp s | t_mem s | t_coll s | "
          "bottleneck | MODEL/HLO | note |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in dryrun_records():
        if r["status"] != "ok" or r["mesh"] != "single":
            continue
        rf = r["roofline"]
        dom = max(rf["t_compute"], rf["t_memory"], rf["t_collective"])
        note = ""
        if rf["useful_ratio"] < 0.3:
            note = "head-repl. waste" if "moe" in r["arch"] or "qwen" in \
                r["arch"] else "low-intensity"
        print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
              f"{rf['t_compute']:.3f} | {rf['t_memory']:.3f} | "
              f"{rf['t_collective']:.3f} | {rf['bottleneck']} | "
              f"{rf['useful_ratio']:.2f} | {note} |")
    # skipped cells
    for r in dryrun_records():
        if r["status"] == "skipped" and r["mesh"] == "single":
            print(f"| {r['arch']} | {r['shape']} | single | — | — | — | "
                  f"skip | — | {r['reason'][:48]} |")


def calibration_table():
    rep = json.loads((RUNS / "adsala" / "calibration_report.json"
                      ).read_text())
    print("| subroutine | best model | gather s | samples | knobs |")
    print("|---|---|---|---|---|")
    for e in rep:
        print(f"| {e['prec']}{e['op']} | {e['best_model']} | "
              f"{e['gather_seconds']} | {e['n_samples']} | {e['n_knobs']} |")


def table7():
    f = RUNS / "adsala" / "table7_speedup.json"
    if not f.exists():
        print("(table7 not yet generated)")
        return
    data = json.loads(f.read_text())
    print("| subroutine | mean | std | min | 25% | 50% | 75% | max |")
    print("|---|---|---|---|---|---|---|---|")
    for sub, v in data.items():
        s = v["stats"]
        print(f"| {sub} | {s['mean']:.2f} | {s['std']:.2f} | {s['min']:.2f} |"
              f" {s['p25']:.2f} | {s['p50']:.2f} | {s['p75']:.2f} | "
              f"{s['max']:.2f} |")


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    if which in ("all", "dryrun"):
        print("### Dry-run table\n")
        dryrun_table()
    if which in ("all", "roofline"):
        print("\n### Roofline table (single-pod)\n")
        roofline_table()
    if which in ("all", "calib"):
        print("\n### Calibration summary\n")
        calibration_table()
    if which in ("all", "table7"):
        print("\n### Table VII speedups\n")
        table7()
