#!/usr/bin/env python
"""Smoke entry point for the shape-bucketed BLAS serving layer.

Runs the full serving story end to end in a few seconds:

  1. mini-installs a tuned model set for the chosen backend (persisted to
     ``--store``, reused on the next invocation),
  2. starts a :class:`repro.serving.BlasService` and pushes a small burst of
     mixed-op, mixed-shape traffic through it,
  3. prints the per-bucket serving stats and the runtime decision counters,
  4. closes the service (persisting the warm-start decision cache), restarts
     it on a FRESH runtime, replays the same shapes, and shows the warm
     runtime performing zero ML model evaluations.

    PYTHONPATH=src python scripts/serve_demo.py
    PYTHONPATH=src python scripts/serve_demo.py --backend cpu_blocked \
        --store runs/serve_demo
"""

from __future__ import annotations

import argparse
import sys
import tempfile
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np  # noqa: E402

from repro.backends import get_backend  # noqa: E402
from repro.core import AdsalaRuntime, ModelRegistry, install_backend  # noqa: E402
from repro.kernels.cpu_blocked import make_operands  # noqa: E402
from repro.serving import BlasService, ServeConfig  # noqa: E402

#: the demo's traffic mix: (op, dims) repeated round-robin
MIX = [
    ("gemm", (64, 64, 64)),
    ("gemm", (96, 64, 96)),
    ("syrk", (64, 48)),
    ("trsm", (64, 32)),
]


def serve_burst(registry: ModelRegistry, backend: str, n: int,
                label: str) -> tuple[AdsalaRuntime, int]:
    runtime = AdsalaRuntime()
    loaded = registry.load_into(runtime)
    cfg = ServeConfig(backend=backend, max_batch=8, linger_ms=2.0)
    with BlasService(runtime=runtime, config=cfg,
                     registry=registry) as svc:
        warm_started = svc.warm_started
        print(f"[serve_demo] {label}: {loaded} tuned models, "
              f"{warm_started} warm-start decisions")
        futs = []
        for i in range(n):
            op, dims = MIX[i % len(MIX)]
            futs.append(svc.submit(
                op, make_operands(op, dims, np.float32, seed=i)))
        for f in futs:
            f.result()
        stats = svc.stats
        print(f"[serve_demo] {label}: {stats.completed}/{stats.submitted} "
              f"served in {stats.batches} batches "
              f"(mean batch {stats.mean_batch:.1f}, "
              f"mean latency {stats.mean_latency * 1e3:.2f} ms)")
        for key, b in sorted(svc.bucket_stats().items()):
            be, op, nbytes, dims = key
            print(f"[serve_demo]   bucket {be}:{op} b{nbytes} {dims}: "
                  f"{b.requests} reqs / {b.batches} batches "
                  f"(max {b.max_batch})")
    s = runtime.stats
    print(f"[serve_demo] {label}: runtime calls={s.calls} "
          f"hits={s.cache_hits} model_evals={s.model_evals} "
          f"defaults={s.default_calls}")
    return runtime, warm_started


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--backend", default="ref")
    p.add_argument("--requests", type=int, default=64)
    p.add_argument("--store", default=None,
                   help="model/cache directory (default: a temp dir)")
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args(argv)

    tmp = None
    if args.store is None:
        tmp = tempfile.TemporaryDirectory()
        store = Path(tmp.name)
    else:
        store = Path(args.store)
    registry = ModelRegistry(store)

    ops_needed = sorted({op for op, _ in MIX})
    have = set()
    for sub in registry.load_all(args.backend):
        have.add(sub.op)
    missing = [op for op in ops_needed if op not in have]
    if missing:
        print(f"[serve_demo] installing tuned {args.backend} models for "
              f"{missing} (~seconds, persisted to {store}) ...")
        install_backend(get_backend(args.backend), ops=missing,
                        n_samples=16, dim_lo=32, dim_hi=128,
                        max_footprint_bytes=1_000_000, tune_trials=1,
                        candidates=("LinearRegression", "DecisionTree"),
                        registry=registry, seed=args.seed)

    cold, cold_warm = serve_burst(registry, args.backend, args.requests,
                                  "cold server")
    warm, _ = serve_burst(registry, args.backend, args.requests,
                          "warm server")

    # with a persistent --store the "cold" server may itself warm-start
    # from a previous invocation's cache — that is success, not failure
    decided_without_evals = cold.stats.model_evals > 0 or cold_warm > 0
    ok = decided_without_evals and warm.stats.model_evals == 0
    if cold_warm:
        print(f"[serve_demo] store already warm ({cold_warm} cached "
              f"decisions reused by the first server)")
    print(f"[serve_demo] warm start skipped all "
          f"{cold.stats.model_evals} cold model evaluations: "
          f"{'ok' if ok else 'FAILED'}")
    if tmp is not None:
        tmp.cleanup()
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
