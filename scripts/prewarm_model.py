#!/usr/bin/env python
"""Ahead-of-time install + decision prewarm for ADSALA-dispatched serving.

Offline half of the "first request pays zero model evaluations" contract:

  1. **harvest** — abstractly trace the routed model's forward / prefill /
     decode_step programs (:func:`repro.roofline.harvest.
     harvest_decision_keys`) for the requested (batch, seq) points; every
     GEMM decision-cache key the server will ever ask for falls out, with
     zero FLOPs executed.
  2. **prune** — score the backend's full knob space with the analytic v5e
     cost oracle at each harvested call site and drop provably-dominated
     candidates (:func:`repro.roofline.costing.prune_dominated_candidates`)
     before paying for calibration.
  3. **install** — run the standard ADSALA install for ``gemm`` over the
     pruned space and persist the artifact through a
     :class:`~repro.core.registry.ModelRegistry`.  ``--timer oracle``
     (default) calibrates against the deterministic cost oracle — fast and
     machine-independent; ``--timer wallclock`` measures the real backend.
  4. **prewarm** — batch-resolve every harvested key through
     ``select_many`` and persist the filled LRU via
     ``save_decision_cache``; a serving process that ``load_into`` +
     ``load_decision_cache``-s this registry then serves its first request
     entirely from cache hits.

The script verifies step 4 by rebuilding a fresh runtime from the persisted
registry, replaying the harvested keys, and asserting **zero** model
evaluations; it exits nonzero if any slip through.

    PYTHONPATH=src python scripts/prewarm_model.py --arch qwen1.5-4b \\
        --registry /tmp/adsala_models --batch 1,8 --seq 128
"""

from __future__ import annotations

import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))


def _parse_ints(text: str) -> tuple[int, ...]:
    return tuple(int(v) for v in text.split(",") if v)


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", default="qwen1.5-4b",
                   help="architecture id (repro.configs registry)")
    p.add_argument("--smoke-config", action="store_true",
                   help="use the reduced smoke config (CI/CPU hosts)")
    p.add_argument("--registry", required=True,
                   help="artifact directory to install into")
    p.add_argument("--batch", default="1,8",
                   help="comma list of serving batch sizes to harvest")
    p.add_argument("--seq", default="128",
                   help="comma list of prefill lengths to harvest")
    p.add_argument("--backend", default="pallas")
    p.add_argument("--timer", choices=("oracle", "wallclock"),
                   default="oracle",
                   help="install calibration timer (oracle = analytic v5e "
                        "cost model, deterministic; wallclock = measure)")
    p.add_argument("--sizes", default="128,256",
                   help="knob-space block edges before pruning")
    p.add_argument("--n-samples", type=int, default=60,
                   help="install-time Halton samples")
    p.add_argument("--tune-trials", type=int, default=2)
    p.add_argument("--prune-slack", type=float, default=0.15,
                   help="oracle-dominance band; <0 disables pruning")
    args = p.parse_args(argv)

    import numpy as np

    from repro.backends import resolve_backend
    from repro.configs import get_config, get_smoke_config
    from repro.core.oracle import oracle_time
    from repro.core.registry import ModelRegistry
    from repro.core.runtime import AdsalaRuntime
    from repro.core.tuner import install_subroutine
    from repro.roofline.costing import prune_dominated_candidates
    from repro.roofline.harvest import harvest_decision_keys

    cfg = (get_smoke_config if args.smoke_config else get_config)(args.arch)
    backend = resolve_backend(args.backend)
    registry = ModelRegistry(args.registry)
    runtime = AdsalaRuntime()

    # 1. harvest --------------------------------------------------------------
    t0 = time.perf_counter()
    keys: list[tuple] = []
    seen: set[tuple] = set()
    for B in _parse_ints(args.batch):
        for S in _parse_ints(args.seq):
            for key in harvest_decision_keys(cfg, batch_size=B, seq_len=S):
                if key not in seen:
                    seen.add(key)
                    keys.append(key)
    ops = sorted({k[1] for k in keys})
    dtype_bytes = sorted({k[2] for k in keys})
    print(f"[prewarm] harvested {len(keys)} decision keys "
          f"(ops={ops}, dtype_bytes={dtype_bytes}) "
          f"in {time.perf_counter() - t0:.2f}s")
    if not keys:
        print("[prewarm] nothing to install (model routes no GEMMs?)")
        return 1

    # 2+3. prune + install, one artifact per (op, dtype_bytes) ---------------
    for op in ops:
        for db in dtype_bytes:
            dims_list = [k[3] for k in keys
                         if k[1] == op and k[2] == db]
            if not dims_list:
                continue
            space = backend.knob_space(op, sizes=_parse_ints(args.sizes))
            full = len(space)
            if args.prune_slack >= 0:
                space = prune_dominated_candidates(
                    op, space, dims_list, dtype_bytes=db,
                    slack=args.prune_slack)
            if args.timer == "oracle":
                timer = lambda dims, knob, _op=op, _db=db: oracle_time(
                    _op, dims, knob, dtype_bytes=_db)
            else:
                timer = backend.timer_fn(op, np.dtype(f"float{db * 8}"))
            lo = max(16, min(min(d) for d in dims_list))
            hi = max(max(d) for d in dims_list)
            sub = install_subroutine(
                op, space, timer, n_samples=args.n_samples,
                dim_lo=lo, dim_hi=max(hi, lo + 1), dtype_bytes=db,
                backend=backend.name, tune_trials=args.tune_trials)
            registry.save(sub)
            runtime.register(sub)
            print(f"[prewarm] installed {backend.name}/{op} b{db}: "
                  f"model={sub.model_name}, knobs {full}->{len(space)} "
                  f"(oracle-pruned), dims [{lo}, {hi}]")

    # 4. prewarm the decision cache ------------------------------------------
    requests = [(op, dims, db, be) for (be, op, db, dims) in keys]
    runtime.select_many(requests, record_hits=False)
    path = registry.save_decision_cache(runtime)
    evals = runtime.stats.for_backend(backend.name).model_evals
    print(f"[prewarm] cached {len(requests)} decisions "
          f"({evals} model evals) -> {path}")

    # verify: a fresh process hydrated from the registry replays every
    # harvested key as a cache hit — zero runtime model evaluations
    fresh = AdsalaRuntime()
    registry.load_into(fresh, backend=backend.name)
    registry.load_decision_cache(fresh)
    for op, dims, db, be in requests:
        fresh.select_or_default(op, dims, db,
                                backend.default_knob(op), backend=be)
    cold_evals = fresh.stats.for_backend(backend.name).model_evals
    print(f"[prewarm] replay from persisted cache: {cold_evals} model "
          f"evals across {len(requests)} keys "
          f"({'OK' if cold_evals == 0 else 'FAIL'})")
    return 0 if cold_evals == 0 else 1


if __name__ == "__main__":
    sys.exit(main())
